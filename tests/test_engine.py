"""Engine/StepExecutor layer: fused-vs-hetero parity, metric contract,
callbacks, calibration pre-fit hook, and executor lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import MethodConfig, slice_ascent_batch
from repro.data.synthetic import ClassificationTask
from repro.engine import (ENGINE_METRIC_KEYS, CheckpointCallback, Engine,
                          EvalCallback, FusedExecutor, HeteroExecutor,
                          LoggingCallback, StalenessTelemetry, ThroughputMeter)
from repro.runtime import ExecutorConfig

TASK = ClassificationTask(n_classes=4, dim=8, seed=3)
STEPS, BATCH = 30, 128


def _loss(params, batch, rng):
    h = jnp.tanh(batch["x"] @ params["w1"])
    logits = h @ params["w2"]
    onehot = jax.nn.one_hot(batch["y"], logits.shape[-1])
    loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
    return loss, {"logits": logits}


def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w1": jax.random.normal(k, (8, 32)) * 0.3,
            "w2": jax.random.normal(jax.random.fold_in(k, 1), (32, 4)) * 0.3}


def _batches(n=STEPS, batch=BATCH, frac=0.5):
    return [{**b, "ascent": slice_ascent_batch(b, frac)}
            for b in TASK.train_batches(batch, n)]


def _make(kind, mcfg=None, xcfg=None, **kw):
    mcfg = mcfg or MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    opt = optim.sgd(0.1, momentum=0.9)
    if kind == "fused":
        return FusedExecutor(_loss, mcfg, opt, donate=False)
    return HeteroExecutor(_loss, mcfg, opt, exec_cfg=xcfg, **kw)


# ---------------------------------------------------------------------------
# parity: both executors drive the same task through the same Engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["fused", "hetero"])
def test_executor_drives_loss_down(kind):
    with _make(kind) as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        report = Engine(ex, _batches()).fit(state, STEPS)
    losses = [h["loss"] for h in report.metrics_history]
    assert report.steps_done == STEPS
    assert losses[-1] < losses[0]
    assert np.isfinite(losses[-1])


def test_executors_emit_identical_contract_keys():
    seen = {}
    for kind in ("fused", "hetero"):
        with _make(kind) as ex:
            state = ex.init_state(_params(), jax.random.PRNGKey(1))
            state, metrics = ex.step(state, _batches(1)[0])
        assert set(ENGINE_METRIC_KEYS) <= set(metrics), (kind, metrics.keys())
        seen[kind] = set(ENGINE_METRIC_KEYS) & set(metrics)
    assert seen["fused"] == seen["hetero"]


def test_hetero_straggler_degrades_to_sgd_past_max_staleness():
    """Injected ascent delay: tau ledger grows, then steps fall back to SGD."""
    xcfg = ExecutorConfig(max_staleness=2, ascent_delay_s=0.5)
    telemetry = StalenessTelemetry(print_summary=False)
    with _make("hetero", xcfg=xcfg) as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        report = Engine(ex, _batches(12), [telemetry]).fit(state, 12)
        summary = ex.ledger.summary()
    t = telemetry.summary()
    assert summary["stale_reuses"] > 0 or summary["sgd_fallbacks"] > 0 \
        or t["sgd_fallbacks"] > 0
    assert np.isfinite(report.metrics_history[-1]["loss"])


# ---------------------------------------------------------------------------
# calibration as a pre-fit hook
# ---------------------------------------------------------------------------

def test_calibrate_pre_fit_reports_and_caps_ascent():
    with _make("hetero", calibrate=True, calibration_probes=1) as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        report = Engine(ex, _batches(6)).fit(state, 6)
        assert report.pre_fit is not None
        frac = report.pre_fit["calibrated_ascent_fraction"]
        assert 0.05 <= frac <= 1.0
        assert ex.calibrated_fraction == frac
        # the slow lane never sees more than the calibrated b'
        capped = ex._cap_ascent(_batches(1)[0])
        assert jax.tree.leaves(capped["ascent"])[0].shape[0] \
            <= max(1, int(round(BATCH * frac)))
        # ... also when the batch carries no pre-sliced "ascent" key
        plain = next(iter(TASK.train_batches(BATCH, 1)))
        capped = ex._cap_ascent(plain)
        assert "ascent" in capped
        assert jax.tree.leaves(capped["ascent"])[0].shape[0] \
            <= max(1, round(BATCH * min(ex.cfg.ascent_fraction, frac)))


def test_fused_has_no_pre_fit_probe():
    with _make("fused") as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        report = Engine(ex, _batches(3)).fit(state, 3)
    assert report.pre_fit is None


# ---------------------------------------------------------------------------
# callbacks + lifecycle
# ---------------------------------------------------------------------------

def test_callbacks_meter_eval_and_logging(capsys):
    val = TASK.valid_set()
    meter = ThroughputMeter(tokens_per_batch=BATCH)
    evals = EvalCallback(lambda st: float(jnp.mean(
        jnp.argmax(_loss(st.params, val, None)[1]["logits"], -1) == val["y"])),
        every=5, total_steps=10)
    with _make("fused") as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        Engine(ex, _batches(10), [meter, evals,
                                  LoggingCallback(every=5)]).fit(state, 10)
    assert len(meter.step_times) == 10
    assert meter.summary()["tokens_per_s"] > 0
    assert len(evals.curve) >= 2
    assert all(0.0 <= acc <= 1.0 for _, acc in evals.curve)
    assert "step " in capsys.readouterr().out


def test_engine_checkpoint_callback_resumes(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.data import PipelineConfig, TokenPipeline
    from repro.models import build_model
    from repro.runtime import InjectedFailure, ResilienceConfig

    cfg = get_config("olmo-1b", reduced=True)
    bundle = build_model(cfg)
    mcfg = MethodConfig(name="async_sam", rho=0.02, ascent_fraction=0.5)
    opt = optim.adamw(1e-3)
    crashed = {"done": False}

    def injector(step):
        if step == 6 and not crashed["done"]:
            crashed["done"] = True
            raise InjectedFailure("simulated node loss")

    with FusedExecutor(bundle.loss_fn, mcfg, opt, donate=False) as ex:
        state = ex.init_state(bundle.init(jax.random.PRNGKey(0)),
                              jax.random.PRNGKey(1))
        pipe = TokenPipeline(cfg, PipelineConfig(global_batch=4, seq_len=16,
                                                 ascent_fraction=0.5,
                                                 prefetch=0))
        cb = CheckpointCallback(CheckpointManager(tmp_path / "ck", keep=2),
                                ResilienceConfig(save_every=5,
                                                 async_save=False))
        report = Engine(ex, pipe, [cb]).fit(state, 10,
                                            failure_injector=injector)
    assert report.restarts == 1
    assert report.steps_done == 10


def test_hetero_checkpoint_restore_resets_ascent_state(tmp_path):
    """A rollback must drop the held/in-flight ascent gradients (they were
    computed against params from the discarded timeline)."""
    from repro.checkpoint import CheckpointManager
    from repro.runtime import InjectedFailure, ResilienceConfig

    class ListPipeline:
        """Minimal state()/restore() wrapper so run_resilient can replay."""

        def __init__(self, batches):
            self.batches = batches
            self.cursor = 0

        def state(self):
            return {"cursor": self.cursor}

        def restore(self, s):
            self.cursor = int(s["cursor"])

        def __iter__(self):
            while self.cursor < len(self.batches):
                b = self.batches[self.cursor]
                self.cursor += 1
                yield b

    crashed = {"done": False}

    def injector(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise InjectedFailure("simulated node loss")

    with _make("hetero") as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        cb = CheckpointCallback(CheckpointManager(tmp_path / "ck", keep=2),
                                ResilienceConfig(save_every=5,
                                                 async_save=False))
        gen_before = ex._inner._gen
        report = Engine(ex, ListPipeline(_batches(12)), [cb]).fit(
            state, 12, failure_injector=injector)
        assert report.restarts == 1 and report.steps_done == 12
        assert ex._inner._gen == gen_before + 1   # reset() ran on restore
    assert np.isfinite(report.metrics_history[-1]["loss"])


def test_executor_close_is_idempotent():
    ex = _make("hetero")
    state = ex.init_state(_params(), jax.random.PRNGKey(1))
    state, _ = ex.step(state, _batches(1)[0])
    ex.close()
    ex.close()          # double close
    ex._inner.close()   # close-after-close on the inner executor
    fx = _make("fused")
    fx.close()
    fx.close()
