"""Shared pytest fixtures.

Device count stays 1 here (the dry-run sets its own XLA_FLAGS in a subprocess;
smoke tests and benches must see the real single CPU device). Mesh-dependent
tests spawn subprocesses via `run_py` with their own device-count flags.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]

# REPRO_FUSED=1 (scripts/tier1.sh --resident): force the fused flat-buffer
# weight-space path on and run its kernels as real Pallas code in interpret
# mode, so the bucket-resident parity/interop tests exercise the kernel
# implementations on CPU instead of the jnp oracles. Tests that pin explicit
# fused=False/True flags are unaffected (explicit override beats the default).
if os.environ.get("REPRO_FUSED") == "1":
    from repro.kernels import ops as _ops
    from repro.utils import buckets as _buckets

    _buckets.set_fused_default(True)
    _ops.set_default_impl("pallas_interpret")

# REPRO_KERNELS=interpret (scripts/tier1.sh --service): run every dispatched
# kernel as real Pallas code in interpret mode WITHOUT forcing the fused
# weight-space default — the service lane uses this so the JOB delta-encode
# kernels (ops.delta_amax / delta_encode_i8) exercise the Pallas
# implementations on CPU while executor behavior stays the platform default.
elif os.environ.get("REPRO_KERNELS") == "interpret":
    from repro.kernels import ops as _ops

    _ops.set_default_impl("pallas_interpret")


@pytest.fixture(scope="session")
def repo_root() -> pathlib.Path:
    return REPO


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    """Run `code` in a fresh python with a fake multi-device CPU platform."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout


@pytest.fixture
def subprocess_py():
    return run_py
