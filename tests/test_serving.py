"""Serving correctness: prefill + stepwise decode == full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model, synth_batch


def _no_drop(cfg):
    """Raise MoE capacity so dispatch drops cannot cause divergence."""
    if cfg.moe:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = _no_drop(get_config(arch, reduced=True))
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    S, n_dec = 12, 4
    batch = synth_batch(cfg, 2, S + n_dec, jax.random.PRNGKey(1))
    full_logits, _ = jax.jit(bundle.forward)(params, batch)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S]
    pre["labels"] = batch["labels"][:, :S]
    logits, cache = jax.jit(
        lambda p, b: bundle.prefill(p, b, pad_to=S + n_dec))(params, pre)

    scale = float(jnp.max(jnp.abs(full_logits.astype(jnp.float32)))) + 1e-6
    errs = [float(jnp.max(jnp.abs(logits[:, -1] - full_logits[:, S - 1])))]
    decode = jax.jit(bundle.decode)
    for t in range(S, S + n_dec):
        logits, cache = decode(params, cache,
                               {"tokens": batch["tokens"][:, t:t + 1]})
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full_logits[:, t]))))
    assert max(errs) / scale < 3e-3, (arch, errs)


def test_decode_cache_pos_advances():
    cfg = get_config("olmo-1b", reduced=True)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = synth_batch(cfg, 2, 8, jax.random.PRNGKey(1))
    _, cache = bundle.prefill(params, batch, pad_to=12)
    assert int(cache["pos"]) == 8
    _, cache = bundle.decode(params, cache, {"tokens": batch["tokens"][:, :1]})
    assert int(cache["pos"]) == 9


def test_sliding_window_decode_ignores_distant_context():
    """mixtral-style SWA: tokens beyond the window cannot change the output."""
    cfg = get_config("mixtral-8x7b", reduced=True)  # window = 8, 2 layers
    cfg = _no_drop(cfg)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    # receptive field of the last position is n_layers*(window-1)=14 tokens;
    # with S=24 token 0 is strictly outside it
    S = 24
    b1 = synth_batch(cfg, 1, S, jax.random.PRNGKey(1))
    b2 = {**b1, "tokens": b1["tokens"].at[:, 0].set(
        (b1["tokens"][:, 0] + 1) % cfg.vocab_size)}
    l1, _ = bundle.forward(params, b1)
    l2, _ = bundle.forward(params, b2)
    # position 13 attends to [6..13] only (window 8): flipping token 0 is invisible
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) < 1e-5
