"""Multi-host ascent service: wire protocol, server/client loopback,
hetero-vs-remote parity, and mid-fit server-death resilience.

The subprocess tests spawn the real ``python -m repro.service.ascent_server``
(the same loopback path `--serve-ascent` drives); every blocking wait has an
explicit deadline so a wedged socket fails the test instead of hanging
tier-1 (`scripts/tier1.sh --service` adds a process-level timeout on top).
"""
import itertools
import time

import jax
import numpy as np
import pytest

from repro import optim
from repro.core import MethodConfig, make_ascent_fn, slice_ascent_batch
from repro.core.ascent import Compressor, _topk_roundtrip
from repro.data.synthetic import ClassificationTask
from repro.engine import Engine, HeteroExecutor, RemoteExecutor, StalenessTelemetry
from repro.runtime import ExecutorConfig
from repro.service import protocol
from repro.service.ascent_server import AscentServer, spawn_server
from repro.service.client import RemoteAscentClient
from repro.service.protocol import FrameType, ProtocolError
from repro.service.testing import MLP_LOSS_SPEC, mlp_init, mlp_loss

TASK = ClassificationTask(n_classes=4, dim=8, seed=3)
BATCH = 64
WIDTHS = (8, 32, 4)


def _params(seed=0):
    return mlp_init(jax.random.PRNGKey(seed), WIDTHS)


def _batches(n, frac=0.5):
    return [{**b, "ascent": slice_ascent_batch(b, frac)}
            for b in TASK.train_batches(BATCH, n)]


def _grad_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (50, 7)),
            "nested": {"b": jax.random.normal(jax.random.fold_in(k, 1), (33,))}}


# ---------------------------------------------------------------------------
# protocol: frames, checksums, pytree/grad codecs, wire-byte model
# ---------------------------------------------------------------------------

def test_frame_roundtrip_and_corruption_detection():
    frame = protocol.encode_frame(FrameType.JOB, b"payload bytes")
    ftype, payload = protocol.decode_frame(frame)
    assert ftype == FrameType.JOB and payload == b"payload bytes"
    # payload corruption -> checksum error
    bad = bytearray(frame)
    bad[-1] ^= 0xFF
    with pytest.raises(ProtocolError, match="checksum"):
        protocol.decode_frame(bytes(bad))
    # bad magic
    with pytest.raises(ProtocolError, match="magic"):
        protocol.decode_frame(b"XXXX" + frame[4:])
    # wrong version
    bad = bytearray(frame)
    bad[4] = 99
    with pytest.raises(ProtocolError, match="version"):
        protocol.decode_frame(bytes(bad))


def test_job_payload_roundtrip():
    params = jax.device_get(_params())
    batch = {"x": np.random.randn(16, 8).astype(np.float32),
             "y": np.arange(16, dtype=np.int32)}
    rng = jax.device_get(jax.random.PRNGKey(7))
    payload = protocol.encode_job(3, 11, params, batch, rng)
    gen, step, p2, b2, r2 = protocol.decode_job(payload)
    assert (gen, step) == (3, 11)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert np.array_equal(a, b)
    assert np.array_equal(batch["y"], b2["y"]) and np.array_equal(rng, r2)


def test_grad_payload_roundtrip_per_kind():
    g = jax.device_get(jax.tree.map(lambda x: x.astype(np.float32),
                                    _grad_tree()))
    treedef = jax.tree.structure(g)

    def roundtrip(tree, comp):
        payload = protocol.encode_grad(1, 2, 3.5, 0.01,
                                       jax.tree.leaves(tree), comp)
        gen, jstep, norm, dt, leaves, pool_meta = protocol.decode_grad(payload)
        assert (gen, jstep) == (1, 2) and norm == 3.5
        assert pool_meta == {}           # no pool prelude unless negotiated
        return jax.tree.unflatten(treedef, leaves)

    # none: bit-exact
    out = roundtrip(g, Compressor("none"))
    assert all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(g), jax.tree.leaves(out)))
    # topk: a k-sparse tree (what the server's compressor hands off) is exact
    frac = 0.1
    sparse = jax.device_get(jax.tree.map(
        lambda x: _topk_roundtrip(x, frac), g))
    out = roundtrip(sparse, Compressor("topk", topk_fraction=frac))
    assert all(np.allclose(a, b, atol=0) for a, b in
               zip(jax.tree.leaves(sparse), jax.tree.leaves(out)))
    # int8: exact up to one quantization ulp of the re-derived scale
    out = roundtrip(g, Compressor("int8"))
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(out)):
        assert np.allclose(a, b, atol=float(np.max(np.abs(a))) / 127 + 1e-7)


@pytest.mark.parametrize("kind,frac", [("none", 0.0), ("int8", 0.0),
                                       ("topk", 0.05), ("topk", 0.5)])
def test_grad_frame_bytes_model_matches_serialized_length(kind, frac):
    """Satellite: wire_bytes models the payload; protocol adds frame overhead
    — together they must equal the actual serialized frame length."""
    g = jax.device_get(_grad_tree())
    comp = Compressor(kind, topk_fraction=frac or 0.01)
    payload = protocol.encode_grad(0, 0, 1.0, 0.0, jax.tree.leaves(g), comp)
    frame = protocol.encode_frame(FrameType.GRAD, payload)
    assert len(frame) == protocol.grad_frame_bytes(comp, g)
    assert len(payload) - protocol.GRAD_FIXED_BYTES >= comp.wire_bytes(g)
    # the revision-3 pool-telemetry prelude is modeled exactly too
    pooled = protocol.encode_grad(0, 0, 1.0, 0.0, jax.tree.leaves(g), comp,
                                  pool=(3, 0.25))
    pframe = protocol.encode_frame(FrameType.GRAD, pooled)
    assert len(pframe) == protocol.grad_frame_bytes(comp, g, pool=True)
    assert len(pframe) - len(frame) == protocol.GRAD_POOL_BYTES
    *_rest, leaves, pool_meta = protocol.decode_grad(pooled, pool=True)
    assert pool_meta == {"pool_depth": 3, "pool_wait_s": 0.25}


def test_parse_addr():
    assert protocol.parse_addr("unix:/tmp/x.sock") == ("unix", "/tmp/x.sock")
    assert protocol.parse_addr("127.0.0.1:7431") == ("tcp", ("127.0.0.1", 7431))
    with pytest.raises(ValueError):
        protocol.parse_addr("7431")


# ---------------------------------------------------------------------------
# v2 JOB payloads: delta codec, shadow sync, exact frame-length model
# ---------------------------------------------------------------------------

def _job_aux(seed=0):
    rs = np.random.RandomState(seed)
    batch = {"x": rs.randn(16, 8).astype(np.float32),
             "y": np.arange(16, dtype=np.int32)}
    rng = np.asarray(jax.device_get(jax.random.PRNGKey(7)))
    return batch, rng


def _caps_v2():
    return True, {"none", "int8", "topk"}


def test_job_v2_snapshot_roundtrip_and_length_model():
    params = jax.device_get(_params())
    batch, rng = _job_aux()
    payload = protocol.encode_job_v2(1, 0, 3, 11, batch, rng, params=params)
    frame = protocol.encode_frame(FrameType.JOB_DELTA, payload)
    assert len(frame) == protocol.job_frame_bytes("none", params, batch, rng)
    assert len(frame) == protocol.job_frame_bytes("int8", params, batch, rng,
                                                  delta=False)
    sync, seq, gen, step, kind, p2, b2, r2, sections = \
        protocol.decode_job_v2(payload)
    assert (sync, seq, gen, step, kind) == (1, 0, 3, 11, "snapshot")
    assert sections == []
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert np.array_equal(a, b)
    assert np.array_equal(batch["y"], b2["y"]) and np.array_equal(rng, r2)


@pytest.mark.parametrize("encoding,frac", [("int8", 0.01), ("topk", 0.1)])
def test_job_delta_roundtrip_shadow_bitwise_and_length_model(encoding, frac):
    """The load-bearing invariant: after every delta the server's numpy
    shadow equals the client encoder's shadow bit for bit, the reconstructed
    params track the true params within the quantization step, and the frame
    length equals `job_frame_bytes` exactly."""
    from repro.service.delta import JobEncoder, ShadowState
    params = jax.device_get(_grad_tree())
    batch, rng = _job_aux()
    enc = JobEncoder(encoding, topk_fraction=frac, delta=True,
                     caps_fn=_caps_v2)
    srv = ShadowState()
    rs = np.random.RandomState(1)
    for step in range(4):
        job = enc.encode(0, params, batch, rng, step)
        payload = protocol.encode_job_v2(job.sync, job.seq, job.gen, job.step,
                                         job.batch, job.rng, params=job.params,
                                         kind=job.kind, deltas=job.deltas)
        frame = protocol.encode_frame(FrameType.JOB_DELTA, payload)
        assert len(frame) == protocol.job_frame_bytes(
            encoding, params, batch, rng, delta=(job.kind != "snapshot"),
            topk_fraction=frac)
        sync, seq, gen, jstep, kind, p2, b2, r2, sections = \
            protocol.decode_job_v2(payload)
        assert kind == ("snapshot" if step == 0 else encoding)
        if kind == "snapshot":
            srv.install(p2, sync)
        else:
            srv.apply(kind, sections, sync, seq)
        cli_shadow = [np.asarray(jax.device_get(s)) for s in enc._shadow]
        for a, b in zip(cli_shadow, srv.bufs):
            np.testing.assert_array_equal(a, b)
        # the walk keeps the reconstruction within the coder's granularity
        if encoding == "int8":
            for a, b in zip(jax.tree.leaves(srv.params()),
                            jax.tree.leaves(params)):
                amax = float(np.max(np.abs(np.asarray(b)))) or 1.0
                assert np.allclose(a, b, atol=2 * amax / 127 + 1e-7)
        params = jax.tree.map(
            lambda x: x + np.float32(0.02) * rs.randn(*x.shape)
            .astype(np.float32), params)


def test_delta_encoder_error_feedback_converges():
    """With params held FIXED, error feedback drives the topk shadow to the
    true params even though each delta ships only a fraction of entries."""
    from repro.service.delta import JobEncoder
    params = jax.device_get(_grad_tree())
    batch, rng = _job_aux()
    enc = JobEncoder("topk", topk_fraction=0.2, delta=True, caps_fn=_caps_v2)
    for step in range(12):
        enc.encode(0, params, batch, rng, step)
    shadow_tree = None
    from repro.utils import buckets
    host = [np.asarray(jax.device_get(s)) for s in enc._shadow]
    shadow_tree = buckets.host_buckets_to_tree(host, enc._layout,
                                               enc._leaf_dtypes)
    for a, b in zip(jax.tree.leaves(shadow_tree), jax.tree.leaves(params)):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_resync_frame_recovers_skewed_stream():
    """A delta the server's shadow cannot extend draws a RESYNC (not an
    error); a fresh snapshot then re-installs and deltas flow again."""
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    params = jax.device_get(_params())
    batch = jax.device_get(_batches(1)[0]["ascent"])
    rng = np.asarray(jax.device_get(jax.random.PRNGKey(5)))
    from repro.utils import buckets
    layout = buckets.bucket_layout(params)
    sock = protocol.connect(server.address)
    try:
        protocol.send_frame(sock, FrameType.HELLO,
                            protocol.encode_hello(Compressor("none")))
        ftype, payload, _ = protocol.recv_frame(sock, timeout=30.0)
        assert ftype == FrameType.HELLO_ACK
        _, ack = protocol.decode_hello(payload)
        assert ack.get("proto") == protocol.PROTO_REVISION
        assert set(ack.get("job_encodings")) == set(protocol.JOB_ENCODINGS)

        def snapshot(sync):
            protocol.send_frame(sock, FrameType.JOB_DELTA,
                                protocol.encode_job_v2(sync, 0, 0, 0, batch,
                                                       rng, params=params))
            ftype, _p, _ = protocol.recv_frame(sock, timeout=120.0)
            return ftype

        def zero_delta(sync, seq):
            deltas = [(1.0, np.zeros(g.size, np.int8)) for g in layout.groups]
            protocol.send_frame(
                sock, FrameType.JOB_DELTA,
                protocol.encode_job_v2(sync, seq, 0, 0, batch, rng,
                                       kind="int8", deltas=deltas))
            ftype, _p, _ = protocol.recv_frame(sock, timeout=120.0)
            return ftype

        assert snapshot(1) == FrameType.GRAD
        assert zero_delta(1, 1) == FrameType.GRAD       # extends the shadow
        assert zero_delta(1, 5) == FrameType.RESYNC     # seq gap -> resync
        assert zero_delta(2, 1) == FrameType.RESYNC     # unknown sync
        assert server.resyncs_sent == 2
        assert snapshot(2) == FrameType.GRAD            # re-install
        assert zero_delta(2, 1) == FrameType.GRAD       # stream flows again
    finally:
        sock.close()
        server.close()


def test_corrupted_job_delta_drops_connection_without_poisoning_shadow():
    """A checksummed-but-malformed JOB_DELTA must drop the connection before
    any buffer is touched; the server survives and serves the next client."""
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    params = jax.device_get(_params())
    batch = jax.device_get(_batches(1)[0]["ascent"])
    rng = np.asarray(jax.device_get(jax.random.PRNGKey(5)))
    from repro.utils import buckets
    layout = buckets.bucket_layout(params)

    def connect():
        sock = protocol.connect(server.address)
        protocol.send_frame(sock, FrameType.HELLO,
                            protocol.encode_hello(Compressor("none")))
        ftype, _p, _ = protocol.recv_frame(sock, timeout=30.0)
        assert ftype == FrameType.HELLO_ACK
        return sock

    sock = connect()
    try:
        protocol.send_frame(sock, FrameType.JOB_DELTA,
                            protocol.encode_job_v2(1, 0, 0, 0, batch, rng,
                                                   params=params))
        ftype, _p, _ = protocol.recv_frame(sock, timeout=120.0)
        assert ftype == FrameType.GRAD
        # truncated delta: the frame itself is valid (crc over the truncated
        # payload), the payload is not — decode must raise server-side and
        # the connection must drop without a half-applied shadow
        deltas = [(1.0, np.zeros(g.size, np.int8)) for g in layout.groups]
        good = protocol.encode_job_v2(1, 1, 0, 0, batch, rng,
                                      kind="int8", deltas=deltas)
        protocol.send_frame(sock, FrameType.JOB_DELTA, good[:-3])
        with pytest.raises((ConnectionError, TimeoutError)):
            protocol.recv_frame(sock, timeout=30.0)
    finally:
        sock.close()
    # the helper is still up: a fresh connection full-syncs and exchanges
    sock = connect()
    try:
        protocol.send_frame(sock, FrameType.JOB_DELTA,
                            protocol.encode_job_v2(1, 0, 0, 0, batch, rng,
                                                   params=params))
        ftype, _p, _ = protocol.recv_frame(sock, timeout=120.0)
        assert ftype == FrameType.GRAD
    finally:
        sock.close()
        server.close()


def test_new_client_old_server_degrades_to_full_snapshots():
    """Satellite: a delta-configured client against a revision-1 server must
    keep training on legacy full-snapshot JOB frames — no codec error, no
    drops, no JOB_DELTA frames on the wire."""
    server = AscentServer(mlp_loss, legacy_hello=True)
    server.serve_in_thread()
    client = RemoteAscentClient(server.address, Compressor("none"),
                                job_encoding="int8", job_delta=True)
    try:
        params = jax.device_get(_params())
        batch = jax.device_get(_batches(1)[0]["ascent"])
        for step in range(3):
            assert client.submit(0, params, batch, jax.random.PRNGKey(step),
                                 step)
            got = client.poll(block=True, timeout=120.0)
            assert got is not None and got[1] is not None
        assert client._v2_ok is False
        assert client.last_job_kind == "snapshot"
        assert client.job_encoder.delta_jobs == 0
        assert client.job_encoder.snapshot_jobs == 3
        assert client.drops == 0 and client.exchanges == 3
        assert server.deltas_applied == 0 and server.shadow_installs == 0
    finally:
        client.close()
        server.close()


@pytest.mark.parametrize("encoding", ["int8", "topk"])
def test_loopback_delta_exchange_tracks_true_gradient(encoding):
    """Delta-encoded JOBs: the server computes on its shadow reconstruction,
    so the gradient must track the true-params gradient (not bitwise);
    measured JOB frame bytes must equal the model for both job kinds."""
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    client = RemoteAscentClient(server.address, Compressor("none"),
                                job_encoding=encoding, job_delta=True,
                                job_topk_fraction=0.2)
    try:
        params = jax.device_get(_params())
        rng = jax.random.PRNGKey(5)
        batch = jax.device_get(_batches(1)[0]["ascent"])
        rs = np.random.RandomState(0)
        for step in range(4):
            assert client.submit(0, params, batch, rng, step)
            got = client.poll(block=True, timeout=120.0)
            assert got is not None and got[1] is not None
            _, g, norm, meta = got
            assert meta["job_bytes"] + meta["grad_bytes"] == meta["wire_bytes"]
            g_ref, _n, _ = jax.jit(make_ascent_fn(mlp_loss))(params, batch,
                                                             rng)
            num = sum(float(np.sum(a * np.asarray(b))) for a, b in
                      zip(jax.tree.leaves(g),
                          jax.tree.leaves(jax.device_get(g_ref))))
            na = np.sqrt(sum(float(np.sum(np.square(a)))
                             for a in jax.tree.leaves(g)))
            nb = np.sqrt(sum(float(np.sum(np.square(np.asarray(b))))
                             for b in jax.tree.leaves(jax.device_get(g_ref))))
            assert num / (na * nb + 1e-12) > 0.99
            params = jax.tree.map(
                lambda x: x + np.float32(0.01) * rs.randn(*x.shape)
                .astype(np.float32), params)
        host_rng = np.asarray(jax.device_get(rng))
        assert client.job_frame_measured["snapshot"] == \
            protocol.job_frame_bytes(encoding, params, batch, host_rng,
                                     delta=False)
        assert client.job_frame_measured[encoding] == \
            protocol.job_frame_bytes(encoding, params, batch, host_rng,
                                     delta=True, topk_fraction=0.2)
        assert client.job_encoder.delta_jobs == 3
        # the params direction shrank ~4x (whole-frame ratio is diluted at
        # toy scale by the shared batch/rng aux; the olmo-1b budget in
        # benchmarks/table_4_2_hetero.py pins the >=4x acceptance claim)
        if encoding == "int8":
            snap = protocol.job_frame_breakdown(encoding, params, batch,
                                                host_rng, delta=False)
            dlt = protocol.job_frame_breakdown(encoding, params, batch,
                                               host_rng, delta=True)
            measured_snap = client.job_frame_measured["snapshot"] - snap["aux"]
            measured_dlt = client.job_frame_measured["int8"] - dlt["aux"]
            assert measured_snap == snap["params"]
            assert measured_dlt == dlt["params"]
            assert measured_snap >= 4.0 * measured_dlt
    finally:
        client.close()
        server.close()


# ---------------------------------------------------------------------------
# server/client exchange (in-process server thread: fast, no subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["none", "int8"])
def test_loopback_exchange_matches_local_ascent(kind):
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    client = RemoteAscentClient(server.address,
                                Compressor(kind, topk_fraction=0.1))
    try:
        params = jax.device_get(_params())
        batch = jax.device_get(_batches(1)[0]["ascent"])
        rng = jax.random.PRNGKey(5)
        assert client.submit(0, params, batch, rng, 0)
        got = client.poll(block=True, timeout=120.0)
        assert got is not None, "no gradient came back"
        gen, g, norm, meta = got
        assert gen == 0
        assert meta["wire_bytes"] > 0 and meta["rtt_s"] > 0
        # measured GRAD frame length == the protocol's exact model (a
        # proto-3 pair always carries the pool-telemetry prelude)
        assert meta["wire_in_bytes"] == protocol.grad_frame_bytes(
            client._compressor, g, pool=True)
        assert "pool_depth" in meta and "pool_wait_s" in meta
        g_ref, n_ref, _ = jax.jit(make_ascent_fn(mlp_loss))(params, batch, rng)
        if kind == "none":
            assert np.isclose(norm, float(n_ref), rtol=1e-5)
            for a, b in zip(jax.tree.leaves(g),
                            jax.tree.leaves(jax.device_get(g_ref))):
                assert np.allclose(a, b, atol=1e-6)
        else:   # lossy channel: direction preserved, not bits
            cos = sum(float(np.sum(a * np.asarray(b))) for a, b in
                      zip(jax.tree.leaves(g), jax.tree.leaves(
                          jax.device_get(g_ref))))
            assert cos > 0
    finally:
        client.close()
        server.close()


def test_server_compute_error_keeps_connection(capsys):
    """A failing server-side exchange comes back as an ERROR frame: the
    client records and surfaces it, the connection survives, and the next
    well-formed job succeeds on the same socket."""
    server = AscentServer(mlp_loss)
    server.serve_in_thread()
    client = RemoteAscentClient(server.address, Compressor("none"))
    try:
        params = jax.device_get(_params())
        bad = {"x": np.ones((4, 3), np.float32),    # wrong feature dim
               "y": np.zeros(4, np.int32)}
        assert client.submit(0, params, bad, jax.random.PRNGKey(0), 0)
        deadline = time.monotonic() + 60
        while client.server_errors == 0 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert client.server_errors == 1 and "server error" in client.last_error
        good = jax.device_get(_batches(1)[0]["ascent"])
        assert client.submit(0, params, good, jax.random.PRNGKey(0), 0)
        got = client.poll(block=True, timeout=120.0)
        if got is not None and got[1] is None:
            # the failed job's lost-exchange sentinel; the real result follows
            got = client.poll(block=True, timeout=120.0)
        assert got is not None and got[0] == 0 and got[1] is not None
        assert client.drops == 0          # the socket was never torn down
        assert server.connections == 1    # same connection throughout
    finally:
        client.close()
        server.close()


def test_unix_socket_exchange(tmp_path):
    server = AscentServer(mlp_loss, bind=f"unix:{tmp_path}/ascent.sock")
    server.serve_in_thread()
    assert server.address.startswith("unix:")
    client = RemoteAscentClient(server.address, Compressor("none"))
    try:
        params = jax.device_get(_params())
        batch = jax.device_get(_batches(1)[0]["ascent"])
        assert client.submit(0, params, batch, jax.random.PRNGKey(5), 0)
        got = client.poll(block=True, timeout=120.0)
        assert got is not None and got[0] == 0
    finally:
        client.close()
        server.close()
    # rebinding the same path must work (stale socket files are unlinked)
    server2 = AscentServer(mlp_loss, bind=f"unix:{tmp_path}/ascent.sock")
    server2.start()
    server2.close()


def test_client_never_connected_closes_promptly():
    """Satellite: shutdown-safe join — a client pointed at a dead address
    must not hang close()."""
    client = RemoteAscentClient("127.0.0.1:1", Compressor("none"),
                                reconnect_backoff_s=0.05)
    time.sleep(0.3)          # let the worker cycle through failed connects
    t0 = time.perf_counter()
    client.close()
    client.close()           # idempotent
    assert time.perf_counter() - t0 < 8.0
    assert not client._thread.is_alive()


def test_executor_close_with_unreachable_server_does_not_hang():
    ex = RemoteExecutor(mlp_loss, MethodConfig(name="async_sam"),
                        optim.sgd(0.1),
                        exec_cfg=ExecutorConfig(ascent_addr="127.0.0.1:1",
                                                reconnect_backoff_s=0.05))
    t0 = time.perf_counter()
    ex.close()
    ex.close()
    assert time.perf_counter() - t0 < 8.0


# ---------------------------------------------------------------------------
# loopback subprocess: parity + resilience (the acceptance criteria)
# ---------------------------------------------------------------------------

def _fit(executor, steps=8):
    with executor as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        report = Engine(ex, _batches(steps)).fit(state, steps)
    return report


def test_remote_matches_hetero_step_for_step():
    """Acceptance: loopback --executor remote == --executor hetero on a fixed
    seed — same tau schedule, same losses — under the lockstep test mode
    (both lanes then consume every submitted gradient exactly one step
    later, removing queue-timing nondeterminism)."""
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    opt = optim.sgd(0.1, momentum=0.9)
    rep_h = _fit(HeteroExecutor(mlp_loss, mcfg, opt,
                                exec_cfg=ExecutorConfig(lockstep=True)))
    rep_r = _fit(RemoteExecutor(
        mlp_loss, mcfg, opt,
        exec_cfg=ExecutorConfig(lockstep=True, serve_ascent=True,
                                loss_spec=MLP_LOSS_SPEC)))
    taus_h = [h["tau"] for h in rep_h.metrics_history]
    taus_r = [h["tau"] for h in rep_r.metrics_history]
    assert taus_h == taus_r == [0.0] + [1.0] * (len(taus_h) - 1)
    losses_h = [h["loss"] for h in rep_h.metrics_history]
    losses_r = [h["loss"] for h in rep_r.metrics_history]
    np.testing.assert_allclose(losses_r, losses_h, rtol=1e-6, atol=1e-7)
    # remote metrics carry the wire telemetry; hetero's do not. wire_bytes
    # stays the sum of the per-direction split (backward compat)
    last = rep_r.metrics_history[-1]
    assert "wire_bytes" in last and "rtt_s" in last
    assert last["job_bytes"] + last["grad_bytes"] == last["wire_bytes"]
    assert "wire_bytes" not in rep_h.metrics_history[-1]


def test_remote_loopback_drives_loss_down_vs_fused():
    """Loopback remote training descends like the single-process executors."""
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    opt = optim.sgd(0.1, momentum=0.9)
    steps = 25
    rep = _fit(RemoteExecutor(
        mlp_loss, mcfg, opt,
        exec_cfg=ExecutorConfig(lockstep=True, serve_ascent=True,
                                loss_spec=MLP_LOSS_SPEC)), steps=steps)
    losses = [h["loss"] for h in rep.metrics_history]
    assert rep.steps_done == steps
    assert np.isfinite(losses[-1]) and losses[-1] < losses[0]


def test_server_killed_midfit_training_recovers(tmp_path):
    """Acceptance: killing the ascent server mid-fit must not crash the run —
    the loopback executor respawns it, the client reconnects (dropping the
    in-flight exchange), and the tau telemetry records the gap."""
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    opt = optim.sgd(0.05, momentum=0.9)
    xcfg = ExecutorConfig(serve_ascent=True, loss_spec=MLP_LOSS_SPEC,
                          max_staleness=2, max_server_respawns=1,
                          reconnect_backoff_s=0.1)
    telemetry = StalenessTelemetry(
        print_summary=False, jsonl_path=tmp_path / "remote.jsonl")
    pool = _batches(50)
    batches = ({**b} for b in itertools.cycle(pool))

    with RemoteExecutor(mlp_loss, mcfg, opt, exec_cfg=xcfg) as ex:
        eng = Engine(ex, batches, [telemetry])
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        # phase 1: step until the remote lane delivered its first gradient
        deadline = time.monotonic() + 120
        m = {"perturbed": 0.0}
        while time.monotonic() < deadline and m["perturbed"] != 1.0:
            state, m = ex.step(state, next(batches))
            time.sleep(0.02)
        assert m["perturbed"] == 1.0, "remote lane never delivered"
        assert m["wire_bytes"] > 0 and m["rtt_s"] > 0

        ex.server.proc.kill()
        ex.server.proc.wait()

        # phase 2: keep stepping through the outage; the run must keep
        # completing steps (tau grows, SGD fallback) and eventually recover
        saw_gap = recovered = False
        deadline = time.monotonic() + 180
        while time.monotonic() < deadline:
            state, m = ex.step(state, next(batches))
            telemetry.on_step(eng, state, m, 0.0)
            if m["perturbed"] == 0.0:
                saw_gap = True
            if saw_gap and m["perturbed"] == 1.0 and m["tau"] == 1:
                recovered = True
                break
            time.sleep(0.02)
        assert saw_gap, "tau telemetry shows no gap after server death"
        assert recovered, "client did not reconnect to the respawned server"
        assert ex.server_respawns == 1
        assert ex.client.reconnects >= 1 and ex.client.drops >= 1
    # the jsonl trace records the gap and the wire telemetry
    telemetry.on_fit_end(eng, None)
    import json
    records = [json.loads(l) for l in
               (tmp_path / "remote.jsonl").read_text().splitlines()]
    assert any(r["perturbed"] == 0.0 for r in records)
    assert any(r.get("wire_bytes", 0) > 0 and r.get("rtt_s", 0) > 0
               for r in records)


def _lockstep_delta_run(steps=12, kill_at=None):
    """One lockstep remote run with int8 JOB deltas; optionally kill the
    loopback server right before step `kill_at` (it respawns)."""
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    opt = optim.sgd(0.1, momentum=0.9)
    xcfg = ExecutorConfig(lockstep=True, serve_ascent=True,
                          loss_spec=MLP_LOSS_SPEC, job_compress="int8",
                          job_delta=True, max_server_respawns=2,
                          reconnect_backoff_s=0.1)
    losses, stats = [], {}
    with RemoteExecutor(mlp_loss, mcfg, opt, exec_cfg=xcfg) as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        for i, b in enumerate(_batches(steps)):
            if kill_at is not None and i == kill_at:
                ex.server.proc.kill()
                ex.server.proc.wait()
            state, m = ex.step(state, b)
            losses.append(float(m["loss"]))
        stats = dict(respawns=ex.server_respawns,
                     reconnects=ex.client.reconnects,
                     retried=ex.client.retried_exchanges,
                     resyncs=ex.client.job_encoder.resyncs,
                     snapshots=ex.client.job_encoder.snapshot_jobs,
                     deltas=ex.client.job_encoder.delta_jobs)
    return losses, stats


def test_server_killed_midfit_delta_stream_reconverges_bitwise():
    """Satellite: killing the server mid-fit under lockstep with int8 JOB
    deltas must be invisible to the schedule — the client reconnects to the
    respawned server and falls back to a full-snapshot JOB of its shadow
    (exactly the params the lost delta encoded), so every loss matches the
    never-disconnected run bit for bit."""
    base, base_stats = _lockstep_delta_run()
    killed, stats = _lockstep_delta_run(kill_at=6)
    assert base_stats["respawns"] == 0 and base_stats["resyncs"] == 0
    assert stats["respawns"] == 1, stats
    assert stats["reconnects"] >= 1
    # the recovery went through the full-snapshot fallback: either the
    # in-flight exchange was resent as a snapshot (retried>0) or the next
    # delta drew a RESYNC from the fresh server (resyncs>0)
    assert stats["retried"] + stats["resyncs"] >= 1, stats
    assert stats["snapshots"] >= 2        # initial sync + the resync
    assert np.array_equal(np.asarray(killed), np.asarray(base)), \
        (base, killed)


def test_remote_calibration_probe_measures_the_wire():
    """calibrate() on the remote lane runs real round trips to the server."""
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    opt = optim.sgd(0.1, momentum=0.9)
    with RemoteExecutor(mlp_loss, mcfg, opt, calibrate=True,
                        calibration_probes=1,
                        exec_cfg=ExecutorConfig(
                            serve_ascent=True,
                            loss_spec=MLP_LOSS_SPEC)) as ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(1))
        report = Engine(ex, _batches(3)).fit(state, 3)
    assert report.pre_fit is not None
    frac = report.pre_fit["calibrated_ascent_fraction"]
    assert 0.05 <= frac <= 1.0
    assert ex.client.exchanges >= 2   # warmup + timed probe at minimum


def test_spawn_server_bad_loss_spec_fails_fast():
    with pytest.raises(RuntimeError, match="failed to start"):
        spawn_server("repro.service.testing:does_not_exist",
                     startup_timeout_s=60.0)
