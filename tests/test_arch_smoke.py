"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates its REDUCED config and runs one forward and
one AsyncSAM train step on CPU, asserting output shapes and finiteness. The
full configs are exercised abstractly in test_dryrun/the dry-run itself.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs import ARCH_IDS, get_config
from repro.core import MethodConfig, init_train_state, make_method
from repro.models import build_model, synth_batch

B, S = 2, 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = synth_batch(cfg, B, S, jax.random.PRNGKey(1))
    logits, aux = jax.jit(bundle.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_async_sam_train_step(arch):
    cfg = get_config(arch, reduced=True)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    method = make_method(mcfg)
    opt = optim.adamw(1e-3)
    state = init_train_state(params, opt, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(bundle.loss_fn, opt))
    batch = synth_batch(cfg, B, S, jax.random.PRNGKey(2), ascent_fraction=0.5)
    for _ in range(2):
        state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert float(metrics["perturbed"]) == 1.0  # second step uses a_{t-1}
    # params actually moved
    moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), state.params, params)
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen3-8b", "zamba2-1.2b",
                                  "rwkv6-7b", "deepseek-v2-lite-16b"])
def test_short_training_reduces_loss(arch):
    """~30 steps on the synthetic Markov LM must beat the first-step loss."""
    cfg = get_config(arch, reduced=True)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    mcfg = MethodConfig(name="async_sam", rho=0.02, ascent_fraction=0.5)
    method = make_method(mcfg)
    opt = optim.adamw(3e-3)
    state = init_train_state(params, opt, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(bundle.loss_fn, opt))

    from repro.data import PipelineConfig, TokenPipeline
    pipe = TokenPipeline(cfg, PipelineConfig(global_batch=8, seq_len=32,
                                             ascent_fraction=0.5, prefetch=0))
    it = iter(pipe)
    first = None
    for i in range(30):
        state, m = step(state, next(it))
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first - 0.05, (first, float(m["loss"]))
