"""Elastic executor + chaos harness: preemption-surviving mesh resizes.

In-process tests cover the pieces (chaos schedules, rolling restart budgets,
`buckets.rebucket`, the batched `reshard_state`, the meshless hetero resize
path); the subprocess tests pin the acceptance criteria on a fake
multi-device CPU platform: a shrink->grow->shrink chaos run tracks an
uninterrupted run's loss trajectory, a crash-kind device loss restores the
last checkpoint onto the survivor mesh, a checkpoint written on an 8-device
mesh restores into a live 4-device fit (and into a bucket-resident one), and
a remote-lane fit survives a descent resize with the ascent pool kept
serving (RESYNC evidence in the jsonl, no server restart).

`scripts/tier1.sh --elastic` runs this file under a hard timeout with
interpret-mode kernels, mirroring the --service/--pool lanes.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import MethodConfig
from repro.engine import ElasticExecutor, Engine, FusedExecutor, HeteroExecutor
from repro.runtime import (ChaosSchedule, DeviceLoss, MeshEvent, RestartBudget,
                           make_sized_mesh, parse_schedule, reshard_state)
from repro.utils import buckets


def _mlp_loss(params, batch, rng):
    h = jnp.tanh(batch["x"] @ params["w1"])
    logits = h @ params["w2"]
    onehot = jax.nn.one_hot(batch["y"], logits.shape[-1])
    loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
    return loss, {"logits": logits}


def _mlp_params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w1": jax.random.normal(k, (8, 32)) * 0.3,
            "w2": jax.random.normal(jax.random.fold_in(k, 1), (32, 4)) * 0.3}


def _batch(seed=0, n=64):
    k = jax.random.PRNGKey(100 + seed)
    return {"x": jax.random.normal(k, (n, 8)),
            "y": jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, 4)}


# ---------------------------------------------------------------------------
# chaos schedule: deterministic, fire-once, both consumption surfaces
# ---------------------------------------------------------------------------

def test_chaos_schedule_fires_once_in_order():
    s = ChaosSchedule([MeshEvent(10, 8), MeshEvent(5, 4)])
    assert s.poll(4) is None
    ev = s.poll(7)
    assert (ev.step, ev.devices) == (5, 4)      # sorted by step
    assert s.poll(7) is None                     # fired exactly once
    assert s.poll(10).devices == 8
    assert s.poll(10 ** 6) is None
    assert s.pending == ()


def test_chaos_schedule_failure_injector_surface():
    s = ChaosSchedule([MeshEvent(3, 4), MeshEvent(6, 2, kind="crash")])
    s(3)                                         # resize: skipped, no raise
    with pytest.raises(DeviceLoss) as ei:
        s(6)
    assert ei.value.event.devices == 2
    s(6)                                         # crash fired once only


def test_parse_schedule():
    s = parse_schedule("40:4, 80:8 ,120:2:crash")
    kinds = [(e.step, e.devices, e.kind) for e in s.pending]
    assert kinds == [(40, 4, "resize"), (80, 8, "resize"), (120, 2, "crash")]
    with pytest.raises(ValueError, match="STEP:DEVICES"):
        parse_schedule("40")
    with pytest.raises(ValueError, match="kind"):
        parse_schedule("40:4:explode")
    with pytest.raises(ValueError, match="empty"):
        parse_schedule(" , ")


# ---------------------------------------------------------------------------
# rolling-window restart budget
# ---------------------------------------------------------------------------

def test_restart_budget_rolling_window_forgets_old_failures():
    now = [0.0]
    b = RestartBudget(2, window_s=10.0, clock=lambda: now[0])
    assert b.spend() == 1
    now[0] = 5.0
    assert b.spend() == 2
    now[0] = 12.0                       # t=0 event left the window
    assert b.spend() == 2
    now[0] = 13.0                       # three events within 10s -> over
    with pytest.raises(RuntimeError, match="restart budget"):
        b.spend()
    assert b.total == 4


def test_restart_budget_lifetime_matches_legacy():
    b = RestartBudget(1)
    b.spend()
    with pytest.raises(RuntimeError, match="lifetime"):
        b.spend()


# ---------------------------------------------------------------------------
# buckets.rebucket: the direct buffer-level regroup edge
# ---------------------------------------------------------------------------

def test_rebucket_unchanged_layout_passes_buffers_through():
    st = buckets.BucketedState.from_tree(
        {"a": jnp.arange(6, dtype=jnp.float32),
         "b": jnp.ones((2, 2), jnp.float32)})
    rb = buckets.rebucket(st, st.layout)
    assert all(x is y for x, y in zip(rb.buffers, st.buffers))


def test_rebucket_regroups_across_dtype_buckets():
    t = {"a": jnp.arange(6, dtype=jnp.float32),
         "b": jnp.arange(4, dtype=jnp.float32).reshape(2, 2),
         "c": jnp.arange(3, dtype=jnp.bfloat16)}
    st = buckets.BucketedState.from_tree(t)
    # target layout: 'b' migrates from the f32 bucket into the bf16 bucket
    variant = {**t, "b": t["b"].astype(jnp.bfloat16)}
    lay = buckets.bucket_layout(variant)
    rb = buckets.rebucket(st, lay)
    want = buckets.BucketedState.from_tree(variant, layout=lay)
    got_t, want_t = rb.to_tree(), want.to_tree()
    assert jax.tree.all(jax.tree.map(
        lambda x, y: x.dtype == y.dtype and jnp.array_equal(x, y),
        got_t, want_t))
    # congruence guards: plain trees and shape mismatches are rejected
    with pytest.raises(TypeError, match="BucketedState"):
        buckets.rebucket(t, lay)
    other = buckets.bucket_layout({"a": jnp.zeros((7,), jnp.float32)})
    with pytest.raises(ValueError, match="congruent"):
        buckets.rebucket(st, other)


def test_residentize_rebuckets_already_resident_input():
    t = {"a": jnp.arange(6, dtype=jnp.float32),
         "b": jnp.ones((2, 2), jnp.float32)}
    like = buckets.BucketedState.from_tree(t)
    again = buckets.residentize(buckets.BucketedState.from_tree(t), like)
    assert buckets.is_bucketed(again)
    assert jax.tree.all(jax.tree.map(jnp.array_equal,
                                     again.to_tree(), like.to_tree()))


# ---------------------------------------------------------------------------
# reshard_state: batched, host hop skipped, resident guard
# ---------------------------------------------------------------------------

def test_reshard_skips_host_roundtrip_on_shared_devices(monkeypatch):
    from repro.configs import get_config
    from repro.core import init_train_state, make_method
    from repro.models import build_model

    cfg = get_config("olmo-1b", reduced=True)
    bundle = build_model(cfg)
    method = make_method(MethodConfig(name="async_sam"))
    state = init_train_state(bundle.init(jax.random.PRNGKey(0)),
                             optim.adamw(1e-3), method, jax.random.PRNGKey(1))

    def boom(*a, **k):
        raise AssertionError("host round-trip taken for an addressable source")

    monkeypatch.setattr(jax, "device_get", boom)
    on_mesh = reshard_state(state, cfg, make_sized_mesh(1))
    monkeypatch.undo()
    assert jax.tree.all(jax.tree.map(
        lambda a, b: jnp.array_equal(a, b),
        jax.device_get(state.params), jax.device_get(on_mesh.params)))


def test_reshard_resident_onto_sharded_mesh_raises():
    class FakeMesh:
        size = 8

    st = buckets.BucketedState.from_tree({"w": jnp.ones((4,), jnp.float32)})
    with pytest.raises(ValueError, match="bucket-resident"):
        reshard_state({"params": st}, None, FakeMesh())
    # unsharded targets pass through / re-place without complaint
    assert reshard_state({"params": st}, None, None)["params"] is st
    moved = reshard_state({"params": st}, None, make_sized_mesh(1))["params"]
    assert jnp.array_equal(moved.buffers[0], st.buffers[0])


# ---------------------------------------------------------------------------
# elastic executor, meshless family: resize = lane resync, budget enforced
# ---------------------------------------------------------------------------

def _hetero_elastic(**kw):
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    return ElasticExecutor(
        HeteroExecutor(_mlp_loss, mcfg, optim.sgd(0.1, momentum=0.9)), **kw)


def test_elastic_hetero_resize_emits_telemetry():
    data = [_batch(i) for i in range(12)]
    sched = ChaosSchedule([MeshEvent(step=5, devices=4)])
    with Engine(_hetero_elastic(), data) as eng:
        state = eng.executor.init_state(_mlp_params(), jax.random.PRNGKey(1))
        rep = eng.fit(state, 12, events=sched)
    assert rep.steps_done == 12
    assert eng.executor.resize_events == 1
    hist = rep.metrics_history
    assert all("mesh_devices" in m for m in hist)
    marked = [m for m in hist if "resize_events" in m]
    assert len(marked) == 1 and marked[0]["mesh_devices"] == 4.0
    assert marked[0]["resize_time_s"] >= 0.0
    assert np.isfinite(hist[-1]["loss"])


def test_elastic_resize_budget_exhaustion_raises():
    data = [_batch(i) for i in range(10)]
    sched = ChaosSchedule([MeshEvent(2, 4), MeshEvent(4, 8), MeshEvent(6, 2)])
    with _hetero_elastic(resize_budget=2) as ex, \
            pytest.raises(RuntimeError, match="resize budget"):
        state = ex.init_state(_mlp_params(), jax.random.PRNGKey(1))
        Engine(ex, data).fit(state, 10, events=sched)


def test_unsatisfiable_graceful_resize_skips_without_killing_the_fit():
    # a mesh-building elastic wrapper asked to grow past the attached device
    # count: the event is skipped with a warning, no budget spent, fit lives
    mcfg = MethodConfig(name="async_sam", rho=0.05, ascent_fraction=0.5)
    inner = HeteroExecutor(_mlp_loss, mcfg, optim.sgd(0.1, momentum=0.9))
    ex = ElasticExecutor(inner, meshless=False, resize_budget=1)
    data = [_batch(i) for i in range(6)]
    sched = ChaosSchedule([MeshEvent(2, 64), MeshEvent(4, 4096)])
    with Engine(ex, data) as eng:
        state = ex.init_state(_mlp_params(), jax.random.PRNGKey(1))
        rep = eng.fit(state, 6, events=sched)
    assert rep.steps_done == 6 and rep.restarts == 0
    assert ex.resize_events == 0          # skipped events spend no budget
    assert all(m["mesh_devices"] == 1.0 for m in rep.metrics_history)


def test_engine_rejects_event_source_on_non_elastic_executor():
    class Poller:                       # poll() but not callable
        def poll(self, step):
            return None

    ex = FusedExecutor(_mlp_loss, MethodConfig(name="sgd"), optim.sgd(0.1))
    with Engine(ex, [_batch(0)]) as eng:
        state = ex.init_state(_mlp_params(), jax.random.PRNGKey(1))
        with pytest.raises(ValueError, match="ElasticExecutor"):
            eng.fit(state, 1, events=Poller())


# ---------------------------------------------------------------------------
# acceptance: shrink->grow->shrink trajectory vs uninterrupted (subprocess)
# ---------------------------------------------------------------------------

def test_chaos_shrink_grow_shrink_matches_uninterrupted(subprocess_py):
    out = subprocess_py("""
        import jax, numpy as np
        from repro import optim
        from repro.configs import get_config
        from repro.core import MethodConfig
        from repro.data import PipelineConfig, TokenPipeline
        from repro.engine import ElasticExecutor, Engine, FusedExecutor
        from repro.models import build_model
        from repro.runtime import ChaosSchedule, MeshEvent, make_sized_mesh

        cfg = get_config('olmo-1b', reduced=True)
        bundle = build_model(cfg)
        STEPS = 18

        def run(events):
            mcfg = MethodConfig(name='async_sam', rho=0.02,
                                ascent_fraction=0.5)
            inner = FusedExecutor(bundle.loss_fn, mcfg, optim.adamw(1e-3),
                                  mesh=make_sized_mesh(8), model_cfg=cfg)
            ex = ElasticExecutor(inner, model_cfg=cfg)
            pipe = TokenPipeline(cfg, PipelineConfig(
                global_batch=8, seq_len=16, ascent_fraction=0.5, prefetch=0))
            with Engine(ex, pipe) as eng:
                state = ex.init_state(bundle.init(jax.random.PRNGKey(0)),
                                      jax.random.PRNGKey(1))
                rep = eng.fit(state, STEPS, events=events)
            return rep, ex

        base, _ = run(None)
        sched = ChaosSchedule([MeshEvent(5, 4), MeshEvent(10, 8),
                               MeshEvent(15, 2)])
        chaos, ex = run(sched)
        assert ex.resize_events == 3, ex.resize_events
        assert chaos.steps_done == base.steps_done == STEPS

        # global batch preserved across every resize => same trajectory
        l_base = [m['loss'] for m in base.metrics_history]
        l_chaos = [m['loss'] for m in chaos.metrics_history]
        np.testing.assert_allclose(l_chaos, l_base, rtol=2e-5, atol=1e-6)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b, rtol=2e-5,
                                                    atol=1e-6),
            jax.device_get(base.final_state.params),
            jax.device_get(chaos.final_state.params))

        # the run ended on the shrunken 2-device mesh
        devs = {d for leaf in jax.tree.leaves(chaos.final_state.params)
                for d in leaf.devices()}
        assert len(devs) == 2, devs
        marked = [m for m in chaos.metrics_history if 'resize_events' in m]
        assert [m['mesh_devices'] for m in marked] == [4.0, 8.0, 2.0]
        print('CHAOS_TRAJECTORY_OK')
    """, devices=8)
    assert "CHAOS_TRAJECTORY_OK" in out


# ---------------------------------------------------------------------------
# acceptance: crash-kind device loss restores onto the survivor mesh
# ---------------------------------------------------------------------------

def test_crash_event_restores_onto_survivors(subprocess_py):
    out = subprocess_py("""
        import jax, numpy as np
        from repro import optim
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.core import MethodConfig
        from repro.data import PipelineConfig, TokenPipeline
        from repro.engine import (CheckpointCallback, ElasticExecutor, Engine,
                                  FusedExecutor)
        from repro.models import build_model
        from repro.runtime import (ChaosSchedule, MeshEvent, ResilienceConfig,
                                   make_sized_mesh)

        cfg = get_config('olmo-1b', reduced=True)
        bundle = build_model(cfg)
        STEPS = 16

        def run(events, subdir):
            mcfg = MethodConfig(name='async_sam', rho=0.02,
                                ascent_fraction=0.5)
            inner = FusedExecutor(bundle.loss_fn, mcfg, optim.adamw(1e-3),
                                  mesh=make_sized_mesh(8), model_cfg=cfg)
            ex = ElasticExecutor(inner, model_cfg=cfg)
            pipe = TokenPipeline(cfg, PipelineConfig(
                global_batch=8, seq_len=16, ascent_fraction=0.5, prefetch=0))
            cb = CheckpointCallback(
                CheckpointManager('/tmp/elastic_ckpt/' + subdir, keep=3),
                ResilienceConfig(save_every=5, async_save=False))
            with Engine(ex, pipe, [cb]) as eng:
                state = ex.init_state(bundle.init(jax.random.PRNGKey(0)),
                                      jax.random.PRNGKey(1))
                rep = eng.fit(state, STEPS, events=events)
            return rep, ex

        clean, _ = run(None, 'clean')
        sched = ChaosSchedule([MeshEvent(8, 4, kind='crash')])
        rep, ex = run(sched, 'chaos')
        assert rep.restarts == 1, rep.restarts
        assert ex.resize_events == 1
        assert rep.steps_done == clean.steps_done == STEPS

        # restored onto the 4 survivors and finished there
        devs = {d for leaf in jax.tree.leaves(rep.final_state.params)
                for d in leaf.devices()}
        assert len(devs) == 4, devs
        # deterministic pipeline + restore => same final state as the clean
        # run (replayed steps ran on the survivor mesh)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b, rtol=2e-5,
                                                    atol=1e-6),
            jax.device_get(clean.final_state.params),
            jax.device_get(rep.final_state.params))
        print('CRASH_RESTORE_OK')
    """, devices=8)
    assert "CRASH_RESTORE_OK" in out


# ---------------------------------------------------------------------------
# satellite: 8-device checkpoint -> live 4-device fit; and -> bucket-resident
# ---------------------------------------------------------------------------

def test_ckpt_8dev_restores_into_4dev_and_resident_fits(subprocess_py):
    out = subprocess_py("""
        import jax, numpy as np
        from repro import optim
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.core import MethodConfig
        from repro.data import PipelineConfig, TokenPipeline
        from repro.engine import (CheckpointCallback, Engine, FusedExecutor)
        from repro.models import build_model
        from repro.runtime import (InjectedFailure, ResilienceConfig,
                                   make_sized_mesh, state_shardings)
        from repro.utils import buckets

        cfg = get_config('olmo-1b', reduced=True)
        bundle = build_model(cfg)
        mcfg = MethodConfig(name='async_sam', rho=0.02, ascent_fraction=0.5)

        def make_pipe():
            return TokenPipeline(cfg, PipelineConfig(
                global_batch=8, seq_len=16, ascent_fraction=0.5, prefetch=0))

        # phase A: fit on the 8-device mesh, checkpointing
        mgr_a = CheckpointManager('/tmp/elastic_interop/a', keep=3)
        ex8 = FusedExecutor(bundle.loss_fn, mcfg, optim.adamw(1e-3),
                            mesh=make_sized_mesh(8), model_cfg=cfg)
        with Engine(ex8, make_pipe(), [CheckpointCallback(
                mgr_a, ResilienceConfig(save_every=4, async_save=False))]) \
                as eng:
            state = ex8.init_state(bundle.init(jax.random.PRNGKey(0)),
                                   jax.random.PRNGKey(1))
            rep_a = eng.fit(state, 8)
        assert rep_a.steps_done == 8

        # phase B: restore that checkpoint into a LIVE 4-device fit
        mesh4 = make_sized_mesh(4)
        ex4 = FusedExecutor(bundle.loss_fn, mcfg, optim.adamw(1e-3),
                            mesh=mesh4, model_cfg=cfg)
        template = ex4.init_state(bundle.init(jax.random.PRNGKey(0)),
                                  jax.random.PRNGKey(1))
        like = jax.eval_shape(lambda: template)
        sh4 = state_shardings(like, cfg, mesh4)
        restored, extras = mgr_a.restore(like, shardings=sh4)
        assert int(restored.step) == 8
        pipe_b = make_pipe()
        pipe_b.restore(extras['pipeline'])
        crashed = []
        def inject(step):
            if step == 11 and not crashed:
                crashed.append(step)
                raise InjectedFailure('node loss on the 4-device mesh')
        cb = CheckpointCallback(
            CheckpointManager('/tmp/elastic_interop/b', keep=3),
            ResilienceConfig(save_every=3, async_save=False), shardings=sh4)
        with Engine(ex4, pipe_b, [cb]) as eng:
            rep_b = eng.fit(restored, 14, failure_injector=inject)
        assert rep_b.steps_done == 14 and rep_b.restarts == 1
        devs = {d for leaf in jax.tree.leaves(rep_b.final_state.params)
                for d in leaf.devices()}
        assert len(devs) == 4, devs
        assert np.isfinite(rep_b.metrics_history[-1]['loss'])

        # phase C: the same 8-device checkpoint enters a bucket-RESIDENT fit
        exr = FusedExecutor(bundle.loss_fn, mcfg, optim.adamw(1e-3),
                            fused_update=True, resident=True)
        template_r = exr.init_state(bundle.init(jax.random.PRNGKey(0)),
                                    jax.random.PRNGKey(1))
        assert buckets.is_resident(template_r)
        like_r = jax.eval_shape(lambda: buckets.to_portable(template_r))
        restored_r, extras_r = mgr_a.restore(like_r)
        state_r = buckets.residentize(restored_r, like=template_r)
        assert buckets.is_resident(state_r) and int(state_r.step) == 8
        pipe_c = make_pipe()
        pipe_c.restore(extras_r['pipeline'])
        crashed_r = []
        def inject_r(step):
            if step == 10 and not crashed_r:
                crashed_r.append(step)
                raise InjectedFailure('node loss mid-resident-fit')
        cb_r = CheckpointCallback(
            CheckpointManager('/tmp/elastic_interop/c', keep=3),
            ResilienceConfig(save_every=3, async_save=False))
        with Engine(exr, pipe_c, [cb_r]) as eng:
            rep_c = eng.fit(state_r, 13, failure_injector=inject_r)
        assert rep_c.steps_done == 13 and rep_c.restarts == 1
        assert buckets.is_resident(rep_c.final_state)
        assert np.isfinite(rep_c.metrics_history[-1]['loss'])
        print('CKPT_ELASTIC_INTEROP_OK')
    """, devices=8)
    assert "CKPT_ELASTIC_INTEROP_OK" in out


# ---------------------------------------------------------------------------
# acceptance: remote-lane fit survives a descent resize, pool stays alive
# ---------------------------------------------------------------------------

def test_remote_resize_keeps_ascent_pool_serving(subprocess_py):
    out = subprocess_py("""
        import json
        import jax, numpy as np
        from repro import optim
        from repro.core import MethodConfig, slice_ascent_batch
        from repro.data.synthetic import ClassificationTask
        from repro.engine import (ElasticExecutor, Engine, RemoteExecutor,
                                  StalenessTelemetry)
        from repro.runtime import ChaosSchedule, ExecutorConfig, MeshEvent
        from repro.service.testing import MLP_LOSS_SPEC, mlp_init, mlp_loss

        TASK = ClassificationTask(n_classes=4, dim=8, seed=3)
        params = mlp_init(jax.random.PRNGKey(0), (8, 32, 4))
        batches = [{**b, 'ascent': slice_ascent_batch(b, 0.5)}
                   for b in TASK.train_batches(64, 16)]
        mcfg = MethodConfig(name='async_sam', rho=0.05, ascent_fraction=0.5)
        xcfg = ExecutorConfig(lockstep=True, serve_ascent=True,
                              loss_spec=MLP_LOSS_SPEC, job_compress='int8',
                              job_delta=True)
        jsonl = '/tmp/elastic_remote.jsonl'
        tel = StalenessTelemetry(print_summary=False, jsonl_path=jsonl)
        RESIZE_AT = 8
        sched = ChaosSchedule([MeshEvent(step=RESIZE_AT, devices=1)])

        ex = RemoteExecutor(mlp_loss, mcfg, optim.sgd(0.1, momentum=0.9),
                            exec_cfg=xcfg)
        el = ElasticExecutor(ex)
        pid = ex.server.proc.pid
        with Engine(el, batches, [tel]) as eng:
            state = el.init_state(params, jax.random.PRNGKey(1))
            rep = eng.fit(state, 16, events=sched)
            # the pool kept serving: same server process, never respawned
            assert ex.server_respawns == 0
            assert ex.server.proc.pid == pid and ex.server.alive()
            enc = ex.client.job_encoder
            # RESYNC: the resize invalidated the JobEncoder shadow, so the
            # post-resize exchange shipped a fresh full snapshot (>= initial
            # sync + resync), then the delta stream resumed
            assert enc.snapshot_jobs >= 2, enc.snapshot_jobs
            assert enc.delta_jobs >= 2, enc.delta_jobs
        assert rep.steps_done == 16 and el.resize_events == 1
        assert np.isfinite(rep.metrics_history[-1]['loss'])

        recs = [json.loads(l) for l in open(jsonl)]
        marked = [r for r in recs if 'resize_events' in r]
        assert len(marked) == 1 and marked[0]['step'] == RESIZE_AT + 1
        # jsonl RESYNC evidence: JOB bytes collapse to the int8 delta size in
        # steady state, and jump back to full-snapshot size right after the
        # resize
        jb = [(r['step'], r['job_bytes']) for r in recs if 'job_bytes' in r]
        pre = [b for s, b in jb if s <= RESIZE_AT]
        post = [b for s, b in jb if s > RESIZE_AT]
        assert pre and post
        snap, delta = max(pre), min(pre)
        assert snap > 1.3 * delta, (snap, delta)  # snapshot beats int8 delta
        assert max(post) >= snap, (max(post), snap)  # resync snapshot again
        assert min(post) <= delta, (min(post), delta)  # then deltas resume
        print('REMOTE_RESIZE_OK')
    """, devices=2)
    assert "REMOTE_RESIZE_OK" in out
