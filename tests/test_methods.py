"""Semantics of the SAM family (the paper's core, Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import (MethodConfig, init_train_state, make_method, perturb)
from repro.utils import trees


def quad_loss(params, batch, rng):
    """L(w) = 0.5 * w' A w with fixed PSD A — gradients are exact: A w."""
    A = batch["A"]
    w = params["w"]
    return 0.5 * w @ A @ w, {"logits": w[None, :]}


def _setup(name, rho=0.1, lr=0.05, **kw):
    cfg = MethodConfig(name=name, rho=rho, **kw)
    method = make_method(cfg)
    opt = optim.sgd(lr)
    return cfg, method, opt


def _quad_batch(dim=6, seed=0):
    key = jax.random.PRNGKey(seed)
    M = jax.random.normal(key, (dim, dim))
    return {"A": M @ M.T / dim + jnp.eye(dim)}


def test_sam_step_matches_closed_form():
    """One SAM step on the quadratic equals the hand-derived update (Eq. 1)."""
    batch = _quad_batch()
    A = batch["A"]
    w0 = jnp.arange(1.0, 7.0)
    cfg, method, opt = _setup("sam", rho=0.1, lr=0.05)
    state = init_train_state({"w": w0}, opt, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(quad_loss, opt))
    state, metrics = step(state, batch)

    g = A @ w0
    w_hat = w0 + 0.1 * g / jnp.linalg.norm(g)
    expected = w0 - 0.05 * (A @ w_hat)
    np.testing.assert_allclose(state.params["w"], expected, rtol=1e-5)


def test_async_sam_first_step_is_sgd_then_uses_stale_gradient():
    """Algorithm 1: step 0 unperturbed; step 1 perturbs with a_0 (tau=1)."""
    batch = _quad_batch()
    A = batch["A"]
    w0 = jnp.arange(1.0, 7.0)
    cfg, method, opt = _setup("async_sam", rho=0.1, lr=0.05,
                              ascent_fraction=1.0, same_batch_ascent=True)
    state = init_train_state({"w": w0}, opt, method, jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(quad_loss, opt))

    state, m0 = step(state, batch)
    assert m0["perturbed"] == 0.0                       # line 8: plain SGD
    w1_expected = w0 - 0.05 * (A @ w0)
    np.testing.assert_allclose(state.params["w"], w1_expected, rtol=1e-5)

    a0 = A @ w0                                         # the stored a_{t-1}
    state, m1 = step(state, batch)
    assert m1["perturbed"] == 1.0
    w1 = w1_expected
    w_hat = w1 + 0.1 * a0 / jnp.linalg.norm(a0)         # stale direction!
    w2_expected = w1 - 0.05 * (A @ w_hat)
    np.testing.assert_allclose(state.params["w"], w2_expected, rtol=1e-5)


def test_async_sam_tracks_sam_when_gradients_stable():
    """On a quadratic with a small lr, consecutive gradients are nearly
    parallel (paper Fig. 1 regime) => AsyncSAM trajectory stays close to SAM."""
    batch = _quad_batch()
    w0 = {"w": jnp.arange(1.0, 7.0)}

    def run(name):
        cfg, method, opt = _setup(name, rho=0.05, lr=0.01,
                                  ascent_fraction=1.0, same_batch_ascent=True)
        state = init_train_state(w0, opt, method, jax.random.PRNGKey(1))
        step = jax.jit(method.make_step(quad_loss, opt))
        for _ in range(50):
            state, m = step(state, batch)
        return state.params["w"], float(m["loss"])

    w_sam, loss_sam = run("sam")
    w_async, loss_async = run("async_sam")
    assert jnp.linalg.norm(w_sam - w_async) / jnp.linalg.norm(w_sam) < 0.02
    assert loss_async == pytest.approx(loss_sam, rel=0.05)


def test_async_sam_cosine_metric_reports_stability():
    batch = _quad_batch()
    cfg, method, opt = _setup("async_sam", rho=0.05, lr=0.01,
                              ascent_fraction=1.0, same_batch_ascent=True)
    state = init_train_state({"w": jnp.arange(1.0, 7.0)}, opt, method,
                             jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(quad_loss, opt))
    for _ in range(3):
        state, m = step(state, batch)
    assert float(m["ascent_cosine"]) > 0.95   # the paper's >0.8 observation


@pytest.mark.parametrize("name", ["sgd", "sam", "gsam", "async_sam",
                                  "looksam", "esam", "aesam", "mesa"])
def test_all_methods_descend_on_quadratic(name):
    batch = _quad_batch()
    # ascent_fraction=1: the quadratic batch has no batch axis to slice
    cfg, method, opt = _setup(name, rho=0.05, lr=0.03, mesa_start_step=5,
                              ascent_fraction=1.0)
    state = init_train_state({"w": jnp.arange(1.0, 7.0)}, opt, method,
                             jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(quad_loss, opt))
    state, m_first = step(state, batch)
    for _ in range(40):
        state, m = step(state, batch)
    assert float(m["loss"]) < float(m_first["loss"]) * 0.3
    assert np.isfinite(float(m["loss"]))


def test_perturbation_radius():
    key = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(key, (17,)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (3, 5))}
    g = {"a": jax.random.normal(jax.random.fold_in(key, 2), (17,)),
         "b": jax.random.normal(jax.random.fold_in(key, 3), (3, 5))}
    w_hat = perturb(params, g, rho=0.37)
    delta = trees.tree_sub(w_hat, params)
    assert float(trees.global_norm(delta)) == pytest.approx(0.37, rel=1e-4)


def test_microbatch_accumulation_matches_full_batch():
    """n_microbatches=4 must reproduce the full-batch gradient step."""
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (16, 8))
    y = jax.random.normal(jax.random.fold_in(key, 1), (16,))

    def loss_fn(params, batch, rng):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    batch = {"x": X, "y": y}
    w0 = {"w": jnp.zeros(8)}
    outs = []
    for nm in (1, 4):
        cfg = MethodConfig(name="async_sam", rho=0.05, n_microbatches=nm,
                           ascent_fraction=0.25)
        method = make_method(cfg)
        opt = optim.sgd(0.1)
        state = init_train_state(w0, opt, method, jax.random.PRNGKey(2))
        step = jax.jit(method.make_step(loss_fn, opt))
        for _ in range(3):
            state, m = step(state, batch)
        outs.append(state.params["w"])
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-5, atol=2e-6)


def test_aesam_takes_sgd_steps_in_flat_regions():
    batch = _quad_batch()
    cfg = MethodConfig(name="aesam", rho=0.05, aesam_lambda_hi=10.0)  # high bar
    method = make_method(cfg)
    opt = optim.sgd(0.01)
    state = init_train_state({"w": jnp.arange(1.0, 7.0)}, opt, method,
                             jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(quad_loss, opt))
    sam_steps = []
    for _ in range(20):
        state, m = step(state, batch)
        sam_steps.append(float(m["sam_step"]))
    # after the 8-step warmup, a huge threshold means pure SGD
    assert sum(sam_steps[10:]) == 0.0


def test_looksam_only_refreshes_every_k():
    batch = _quad_batch()
    cfg = MethodConfig(name="looksam", rho=0.05, looksam_k=3)
    method = make_method(cfg)
    opt = optim.sgd(0.02)
    state = init_train_state({"w": jnp.arange(1.0, 7.0)}, opt, method,
                             jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(quad_loss, opt))
    fresh = []
    for _ in range(9):
        state, m = step(state, batch)
        fresh.append(float(m["fresh"]))
    assert fresh == [1.0, 0.0, 0.0] * 3


def test_async_sam_interval_staleness_cycles():
    """ascent_interval=3: tau cycles 1->2->3 and the held direction is reused."""
    batch = _quad_batch()
    cfg, method, opt = _setup("async_sam", rho=0.05, lr=0.01,
                              ascent_fraction=1.0, ascent_interval=3)
    state = init_train_state({"w": jnp.arange(1.0, 7.0)}, opt, method,
                             jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(quad_loss, opt))
    taus = []
    for _ in range(7):
        state, m = step(state, batch)
        taus.append(int(state.method_state.staleness))
    # refreshes at steps 0,3,6 -> staleness observed after each step
    assert taus == [1, 2, 3, 1, 2, 3, 1]


def test_async_sam_interval_still_descends():
    batch = _quad_batch()
    cfg, method, opt = _setup("async_sam", rho=0.05, lr=0.03,
                              ascent_fraction=1.0, ascent_interval=4)
    state = init_train_state({"w": jnp.arange(1.0, 7.0)}, opt, method,
                             jax.random.PRNGKey(1))
    step = jax.jit(method.make_step(quad_loss, opt))
    state, first = step(state, batch)
    for _ in range(40):
        state, m = step(state, batch)
    assert float(m["loss"]) < float(first["loss"]) * 0.3
