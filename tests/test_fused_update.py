"""Fused flat-buffer weight-space path ≡ per-leaf path.

The fused path (utils.buckets + optim.fused + the single-pass kernels) must be
a drop-in for the per-leaf chain: same opt_state layout, same numbers (exact
summation-order tolerance for fp32 params; bf16 params differ only by the
per-leaf path's intermediate bf16 round-trips, which the fp32 kernels skip).
On CPU the kernels dispatch to the jnp oracles (ops._resolve), so these tests
exercise the full bucketing + chain-recognition + state-rebuild machinery.

The bucket-RESIDENT tests additionally pin the PR-4 invariants: a resident
step traces with ZERO gather/scatter conversion copies, steps allocate no
extra device buffers, and pytree-shaped checkpoints round-trip through
resident executors bitwise (per-leaf save -> resident restore and back).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.core import MethodConfig, init_train_state, make_method
from repro.core.perturb import perturb
from repro.engine import Engine, FusedExecutor, StalenessTelemetry
from repro.optim import configure_fused
from repro.optim.fused import epilogue_hbm_bytes, fused_apply
from repro.utils import buckets, trees

KEY = jax.random.PRNGKey(0)

F32_TOL = dict(rtol=5e-5, atol=5e-6)
BF16_TOL = dict(rtol=2e-2, atol=2e-2)


def _params(dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    return {"w": jax.random.normal(ks[0], (37, 5)).astype(dtype) * 0.3,
            "b": jnp.zeros((5,), dtype),
            "emb": jax.random.normal(ks[1], (11, 3)).astype(dtype)}


def _grads(params, seed=1):
    k = jax.random.PRNGKey(seed)
    return jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(k, x.size),
                                    x.shape).astype(x.dtype), params)


def _loss_fn(params, batch, rng):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _batch(seed=2):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {"x": jax.random.normal(ks[0], (16, 37)),
            "y": jax.random.normal(ks[1], (16, 5))}


def _allclose_trees(a, b, **tol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), **tol)


# ---------------------------------------------------------------------------
# buckets: layout + roundtrip
# ---------------------------------------------------------------------------

def test_bucket_roundtrip_mixed_dtypes():
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.arange(5, dtype=jnp.bfloat16),
            "c": {"d": jnp.ones((2, 2), jnp.float32)}}
    layout = buckets.bucket_layout(tree)
    assert len(layout.groups) == 2          # one bucket per dtype
    bufs = buckets.tree_to_buckets(tree, layout)
    assert sum(b.shape[0] for b in bufs) == trees.tree_size(tree)
    back = buckets.buckets_to_tree(bufs, layout, tree)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_bucket_layout_is_cached():
    tree = _params()
    assert buckets.bucket_layout(tree) is buckets.bucket_layout(
        jax.tree.map(lambda x: x + 1, tree))


def test_congruent_tree_buckets_by_param_layout():
    """An all-fp32 gradient tree follows a mixed-dtype param grouping."""
    params = {"w": jnp.ones((4, 4), jnp.bfloat16), "b": jnp.ones((3,))}
    grads = jax.tree.map(lambda x: jnp.full(x.shape, 2.0, jnp.float32), params)
    layout = buckets.bucket_layout(params)
    gb = buckets.tree_to_buckets(grads, layout)
    assert [b.dtype for b in gb] == [jnp.float32] * len(gb)
    assert sorted(b.shape[0] for b in gb) == [3, 16]


def test_bucketed_reductions_match_tree_ops():
    a, b = _params(), _grads(_params())
    np.testing.assert_allclose(float(buckets.bucketed_sq_norm(a)),
                               float(trees.tree_sq_norm(a)), rtol=1e-6)
    dot, sa, sb = buckets.bucketed_dot_norms(a, b)
    np.testing.assert_allclose(float(dot), float(trees.tree_dot(a, b)),
                               rtol=1e-5)
    np.testing.assert_allclose(float(sa), float(trees.tree_sq_norm(a)),
                               rtol=1e-6)
    np.testing.assert_allclose(float(sb), float(trees.tree_sq_norm(b)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# perturb: fused vs per-leaf
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, F32_TOL),
                                       (jnp.bfloat16, BF16_TOL)])
def test_perturb_fused_matches_per_leaf(dtype, tol):
    params = _params(dtype)
    grad = trees.tree_cast(_grads(_params()), jnp.float32)
    ref = perturb(params, grad, 0.1, fused=False)
    got = perturb(params, grad, 0.1, fused=True)
    assert all(x.dtype == dtype for x in jax.tree.leaves(got))
    _allclose_trees(ref, got, **tol)
    # carried-norm variant (the AsyncSAM call shape)
    norm = trees.global_norm(grad)
    _allclose_trees(perturb(params, grad, 0.1, grad_norm=norm, fused=False),
                    perturb(params, grad, 0.1, grad_norm=norm, fused=True),
                    **tol)


# ---------------------------------------------------------------------------
# optimizer epilogue: fused_apply vs per-leaf chain
# ---------------------------------------------------------------------------

OPTIMIZERS = {
    "sgd_plain": lambda: optim.sgd(0.1),
    "sgd_full": lambda: optim.sgd(0.1, momentum=0.9, nesterov=True,
                                  weight_decay=1e-4, clip_norm=1.0),
    "sgd_mom_wd": lambda: optim.sgd(optim.cosine_schedule(0.1, 50),
                                    momentum=0.9, weight_decay=5e-4),
    "adamw": lambda: optim.adamw(0.01, clip_norm=0.5),
    "adamw_nowd": lambda: optim.adamw(0.01, weight_decay=0.0),
}


@pytest.mark.parametrize("name", sorted(OPTIMIZERS))
def test_fused_apply_matches_per_leaf_chain(name):
    params = _params()
    grads = _grads(params)
    opt = OPTIMIZERS[name]()
    st1 = st2 = opt.init(params)
    p1 = p2 = params
    for _ in range(4):
        upd, st1 = opt.update(grads, st1, p1)
        p1 = optim.apply_updates(p1, upd)
        out = fused_apply(configure_fused(opt, True), grads, st2, p2)
        assert out is not None
        p2, st2, gnorm = out
    assert jax.tree.structure(st1) == jax.tree.structure(st2)
    _allclose_trees(p1, p2, **F32_TOL)
    _allclose_trees(st1, st2, **F32_TOL)
    np.testing.assert_allclose(float(gnorm), float(trees.global_norm(grads)),
                               rtol=1e-6)


def test_fused_apply_declines_unrecognized_chains():
    params = _params()
    grads = _grads(params)
    hand_built = optim.chain(optim.scale_by_adam(),
                             optim.scale_by_learning_rate(0.01))
    assert fused_apply(configure_fused(hand_built, True), grads,
                       hand_built.init(params), params) is None
    masked = optim.adamw(0.01, decay_mask=lambda path: "w" in path)
    assert masked.fused_spec is None
    # disabled spec declines too
    opt = optim.adamw(0.01)
    assert fused_apply(configure_fused(opt, False), grads,
                       opt.init(params), params) is None


def test_fused_default_is_off_on_cpu():
    assert not buckets.fused_path_enabled(None)
    assert buckets.fused_path_enabled(True)


# ---------------------------------------------------------------------------
# end-to-end: method steps, fused vs per-leaf (sgd/adamw x sam/async_sam)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["sam", "async_sam"])
@pytest.mark.parametrize("opt_name,opt_kw", [
    ("sgd", dict(momentum=0.9, weight_decay=1e-4, clip_norm=1.0)),
    ("adamw", dict(clip_norm=1.0)),
])
def test_method_steps_fused_matches_per_leaf(method, opt_name, opt_kw):
    params = _params()
    batch = _batch()
    results = []
    for fused in (False, True):
        mcfg = MethodConfig(name=method, rho=0.05, fused_update=fused)
        opt = configure_fused(optim.make_optimizer(opt_name, 0.05, **opt_kw),
                              fused)
        m = make_method(mcfg)
        state = init_train_state(params, opt, m, jax.random.PRNGKey(3))
        step = jax.jit(m.make_step(_loss_fn, opt))
        metrics = None
        for _ in range(5):
            state, metrics = step(state, batch)
        results.append((state, metrics))
    (s1, m1), (s2, m2) = results
    assert jax.tree.structure(s1) == jax.tree.structure(s2)
    _allclose_trees(s1, s2, **F32_TOL)
    for k in ("loss", "grad_norm"):
        np.testing.assert_allclose(float(m1[k]), float(m2[k]), rtol=1e-5)
    if method == "async_sam":
        for k in ("ascent_norm", "ascent_cosine"):
            np.testing.assert_allclose(float(m1[k]), float(m2[k]),
                                       rtol=1e-4, atol=1e-5)


def test_fused_executor_flag_resolution_and_fit():
    """fused_update=True on the executor drives the loss down like False.

    A forced-fused executor goes bucket-RESIDENT by default (the buffers are
    the source of truth); its final params are viewed back to the pytree
    shape for the comparison.
    """
    params = _params()
    batches = [_batch(seed=s) for s in range(20)]
    finals = {}
    for fused in (False, True):
        ex = FusedExecutor(_loss_fn, MethodConfig(name="async_sam", rho=0.05),
                           optim.adamw(0.01, clip_norm=1.0),
                           donate=False, fused_update=fused)
        assert ex.fused_update is fused
        assert ex.resident is fused     # resident follows the resolved switch
        with ex:
            state = ex.init_state(params, jax.random.PRNGKey(0))
            assert buckets.is_resident(state.params) is fused
            report = Engine(ex, batches).fit(state, 20)
        assert report.metrics_history[-1]["loss"] < report.metrics_history[0]["loss"]
        finals[fused] = report.final_state
    _allclose_trees(finals[False].params,
                    buckets.to_portable(finals[True].params), **F32_TOL)


def test_fused_executor_default_off_on_cpu():
    ex = FusedExecutor(_loss_fn, MethodConfig(name="sgd"), optim.sgd(0.1))
    assert ex.fused_update is False
    ex.close()


# ---------------------------------------------------------------------------
# telemetry jsonl sink
# ---------------------------------------------------------------------------

def test_staleness_telemetry_jsonl_sink(tmp_path):
    path = tmp_path / "telemetry" / "run.jsonl"
    tele = StalenessTelemetry(print_summary=False, jsonl_path=path)
    ex = FusedExecutor(_loss_fn, MethodConfig(name="async_sam", rho=0.05),
                       optim.sgd(0.05, momentum=0.9), donate=False)
    with ex:
        state = ex.init_state(_params(), jax.random.PRNGKey(0))
        Engine(ex, [_batch(seed=s) for s in range(6)], [tele]).fit(state, 6)
    records = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(records) == 6
    assert [r["step"] for r in records] == list(range(1, 7))
    for r in records:
        assert set(r) == {"step", "tau", "perturbed", "step_time_s", "loss"}
        assert r["loss"] is not None
    # steady state: tau=1 from the second step on (first step has no ascent)
    assert records[-1]["tau"] == 1


# ---------------------------------------------------------------------------
# bucket-resident state: buffer-to-buffer steps, no conversions, interop
# ---------------------------------------------------------------------------

def _resident_executor(method="async_sam", **kw):
    return FusedExecutor(_loss_fn, MethodConfig(name=method, rho=0.05),
                         optim.adamw(0.01, clip_norm=1.0),
                         fused_update=True, resident=True, **kw)


def test_resident_state_representation():
    ex = _resident_executor(donate=False)
    state = ex.init_state(_params(), jax.random.PRNGKey(0))
    assert buckets.is_bucketed(state.params)
    adam = state.opt_state[1]
    assert buckets.is_bucketed(adam.mu) and buckets.is_bucketed(adam.nu)
    assert buckets.is_bucketed(state.method_state.ascent_grad)
    # the view reproduces the exact pytree contract (structure/shape/dtype)
    view = buckets.to_portable(state.params)
    ref = _params()
    assert jax.tree.structure(view) == jax.tree.structure(ref)
    for a, b in zip(jax.tree.leaves(view), jax.tree.leaves(ref)):
        assert a.shape == b.shape and a.dtype == b.dtype
    ex.close()


@pytest.mark.parametrize("method", ["sam", "async_sam"])
@pytest.mark.parametrize("opt_name,opt_kw", [
    ("sgd", dict(momentum=0.9, weight_decay=1e-4, clip_norm=1.0)),
    ("adamw", dict(clip_norm=1.0)),
])
def test_resident_matches_per_leaf(method, opt_name, opt_kw):
    """Bucket-resident fit == per-leaf fit across sgd/adamw x sam/async_sam."""
    params = _params()
    batches = [_batch(seed=s) for s in range(6)]
    finals, metrics = {}, {}
    for resident in (False, True):
        ex = FusedExecutor(_loss_fn, MethodConfig(name=method, rho=0.05),
                           optim.make_optimizer(opt_name, 0.05, **opt_kw),
                           donate=False, fused_update=resident,
                           resident=resident)
        with ex:
            state = ex.init_state(params, jax.random.PRNGKey(0))
            report = Engine(ex, batches).fit(state, 6)
        finals[resident] = buckets.to_portable(report.final_state)
        metrics[resident] = report.metrics_history[-1]
    assert jax.tree.structure(finals[False]) == jax.tree.structure(finals[True])
    _allclose_trees(finals[False], finals[True], **F32_TOL)
    np.testing.assert_allclose(metrics[False]["loss"], metrics[True]["loss"],
                               rtol=1e-5)


def test_resident_step_traces_with_zero_conversion_copies():
    """The whole resident step is buffer -> buffer: tracing it performs no
    tree_to_buckets/buckets_to_tree copies, while the same step over plain
    pytree state re-gathers buckets around every kernel call."""
    batch = _batch()
    realized = {}
    for resident in (False, True):
        ex = FusedExecutor(_loss_fn, MethodConfig(name="async_sam", rho=0.05),
                           optim.adamw(0.01, clip_norm=1.0), donate=False,
                           fused_update=True, resident=resident)
        sds = ex.abstract_state(_params, jax.random.PRNGKey(0))
        with buckets.track_copies() as stats:
            jax.eval_shape(ex._step_raw, sds, batch)
        realized[resident] = stats
        ex.close()
    assert realized[True].total_bytes == 0, realized[True]
    assert realized[True].gathers == realized[True].scatters == 0
    assert realized[False].gathers >= 4 and realized[False].scatters >= 2
    # the modeled resident=False overhead and the trace agree on the sign
    # and rough size of the gap (the model folds the fp32 ascent-grad gather
    # to param dtype, so exact equality is not expected)
    n = trees.tree_size(_params())
    modeled_gap = (epilogue_hbm_bytes(n, 4 * n, fused=True, resident=False)
                   - epilogue_hbm_bytes(n, 4 * n, fused=True, resident=True))
    assert 0.5 * modeled_gap <= realized[False].total_bytes <= 2.0 * modeled_gap


def test_resident_steps_allocate_no_extra_buffers():
    """Donated resident steps are allocation-neutral: after warmup, the count
    of live device arrays is identical from step to step (buffer in, buffer
    out — no gather/scatter temporaries survive, nothing accumulates)."""
    ex = _resident_executor(donate=True, block=True)
    state = ex.init_state(_params(), jax.random.PRNGKey(0))
    batches = [_batch(seed=s) for s in range(6)]
    metrics = None
    with ex:
        for b in batches[:2]:          # warmup: compile + constant caches
            state, metrics = ex.step(state, b)
        baseline = len(jax.live_arrays())
        for b in batches[2:]:
            state, metrics = ex.step(state, b)
            assert len(jax.live_arrays()) == baseline
    del metrics


def test_checkpoint_interop_per_leaf_and_resident(tmp_path):
    """Pytree checkpoints are the interchange format: a per-leaf (PR 1-3-era)
    save restores into a bucket-resident executor and resumes bitwise-equal
    to the directly-converted state; a resident save restores back into a
    per-leaf executor unchanged."""
    params = _params()
    batches = [_batch(seed=s) for s in range(8)]
    mcfg = MethodConfig(name="async_sam", rho=0.05)
    opt = lambda: optim.adamw(0.01, clip_norm=1.0)  # noqa: E731

    # --- per-leaf run to step 3, saved pytree-shaped (the PR 1-3 format)
    ex_pl = FusedExecutor(_loss_fn, mcfg, opt(), donate=False,
                          fused_update=False, resident=False)
    st_pl = ex_pl.init_state(params, jax.random.PRNGKey(0))
    for b in batches[:3]:
        st_pl, _ = ex_pl.step(st_pl, b)
    mgr = CheckpointManager(tmp_path / "ck", keep=3)
    mgr.save(3, st_pl)

    # --- restore into a bucket-resident executor via the portable edge
    ex_r = _resident_executor(donate=False)
    template = ex_r.init_state(params, jax.random.PRNGKey(0))
    like = jax.eval_shape(lambda: buckets.to_portable(template))
    restored, _ = mgr.restore(like, step=3)
    st_restored = buckets.residentize(restored, like=template)
    st_direct = buckets.residentize(st_pl, like=template)

    losses = {}
    finals = {}
    for tag, st in [("restored", st_restored), ("direct", st_direct)]:
        cur, ls = st, []
        for b in batches[3:6]:
            cur, m = ex_r.step(cur, b)
            ls.append(np.asarray(m["loss"]))
        losses[tag] = ls
        finals[tag] = cur
    # bitwise: restore went through .npy files but the values are identical,
    # and the resident steps are deterministic
    for a, b in zip(losses["restored"], losses["direct"]):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(buckets.to_portable(finals["restored"])),
                    jax.tree.leaves(buckets.to_portable(finals["direct"]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # --- and back: resident state saves pytree-shaped, restores per-leaf
    mgr.save(6, buckets.to_portable(finals["restored"]))
    back, _ = mgr.restore(jax.eval_shape(lambda: st_pl), step=6)
    assert jax.tree.structure(back) == jax.tree.structure(st_pl)
    st_after, m = ex_pl.step(back, batches[6])
    assert np.isfinite(float(m["loss"]))
    ex_pl.close()
    ex_r.close()


def test_run_resilient_converts_resident_state_at_the_edge(tmp_path):
    """Engine.fit + CheckpointCallback on a resident executor writes pytree
    checkpoints (layout-stamped) and survives an injected crash by
    re-residentizing the restored state."""
    from repro.engine import CheckpointCallback
    from repro.runtime import ResilienceConfig

    class ListPipe(list):
        def state(self):
            return {"cursor": 0}

        def restore(self, s):
            pass

    batches = ListPipe([_batch(seed=s) for s in range(8)])
    boom = {"armed": True}

    def injector(step):
        if step == 5 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected")

    ex = _resident_executor(donate=False)
    state = ex.init_state(_params(), jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path / "ck", keep=5)
    cb = CheckpointCallback(mgr, ResilienceConfig(save_every=4,
                                                  async_save=False))
    with ex:
        report = Engine(ex, batches, [cb]).fit(state, 8,
                                               failure_injector=injector)
    assert report.steps_done == 8 and report.restarts == 1
    assert buckets.is_resident(report.final_state.params)
    # on-disk: pytree-shaped arrays + the layout stamp in the manifest
    d = mgr.root / "step_00000008"
    manifest = json.loads((d / "manifest.json").read_text())
    paths = [rec["path"] for rec in manifest["leaves"]]
    plain = FusedExecutor(_loss_fn, MethodConfig(name="async_sam", rho=0.05),
                          optim.adamw(0.01, clip_norm=1.0), donate=False,
                          fused_update=False)
    plain_paths = trees.tree_paths(
        plain.init_state(_params(), jax.random.PRNGKey(0)))
    plain.close()
    assert paths == plain_paths
    assert manifest["extras"]["bucket_layout"], "resident saves are stamped"


# ---------------------------------------------------------------------------
# modeled epilogue bytes (perf_cell artifact contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,param_bytes_per", [("adamw", 4),
                                                    ("adamw", 2),
                                                    ("sgd", 4)])
def test_modeled_epilogue_reduction_at_least_2x(family, param_bytes_per):
    n = 1_000_000
    kw = dict(family=family, clip=True, weight_decay=True,
              carried_norm=True)
    unfused = epilogue_hbm_bytes(n, param_bytes_per * n, fused=False, **kw)
    fused = epilogue_hbm_bytes(n, param_bytes_per * n, fused=True, **kw)
    assert unfused / fused >= 2.0, (family, param_bytes_per, unfused / fused)


@pytest.mark.parametrize("family", ["adamw", "sgd"])
@pytest.mark.parametrize("carried_norm", [True, False])
def test_modeled_nonresident_fused_forfeits_the_win(family, carried_norm):
    """resident=False models the gather/scatter-per-call regime: the kernels'
    reduction is eaten by conversion copies (~1x unfused or worse) — exactly
    the gap bucket residency closes."""
    n = 1_000_000
    kw = dict(family=family, clip=True, weight_decay=True, momentum=True,
              carried_norm=carried_norm)
    unfused = epilogue_hbm_bytes(n, 4 * n, fused=False, **kw)
    ceiling = epilogue_hbm_bytes(n, 4 * n, fused=True, resident=True, **kw)
    realized = epilogue_hbm_bytes(n, 4 * n, fused=True, resident=False, **kw)
    assert ceiling < unfused
    assert realized > ceiling
    # the non-resident "win" is no better than ~1.1x of per-leaf
    assert unfused / realized < 1.1, (family, carried_norm, unfused / realized)


def test_bucketed_primitives_accept_threaded_layout_and_resident_operands():
    a, b = _params(), _grads(_params())
    layout = buckets.bucket_layout(a)
    # threading the cached layout changes nothing numerically
    np.testing.assert_allclose(
        float(buckets.bucketed_sq_norm(a, layout)),
        float(buckets.bucketed_sq_norm(a)), rtol=1e-6)
    d1 = buckets.bucketed_dot_norms(a, b, layout=layout)
    d2 = buckets.bucketed_dot_norms(a, b)
    for x, y in zip(d1, d2):
        np.testing.assert_allclose(float(x), float(y), rtol=1e-6)
    # resident operands use their own buffers — same numbers, zero gathers
    ra = buckets.BucketedState.from_tree(a, layout)
    rb = buckets.BucketedState.from_tree(b, layout)
    with buckets.track_copies() as stats:
        d3 = buckets.bucketed_dot_norms(ra, rb)
        sq = buckets.bucketed_sq_norm(ra)
    assert stats.gathers == 0
    for x, y in zip(d3, d2):
        np.testing.assert_allclose(float(x), float(y), rtol=1e-6)
    np.testing.assert_allclose(float(sq), float(trees.tree_sq_norm(a)),
                               rtol=1e-6)
    # resident axpy stays resident
    out = buckets.bucketed_axpy(jnp.float32(0.5), rb, ra)
    assert buckets.is_bucketed(out)
    _allclose_trees(out.to_tree(),
                    jax.tree.map(lambda x, y: 0.5 * y + x, a, b), **F32_TOL)
