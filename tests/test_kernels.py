"""Per-kernel correctness: Pallas (interpret mode) vs the pure-jnp oracles.

Each kernel is swept over shapes and dtypes per the deliverable requirement;
the jnp "fast paths" used on CPU (flash scan, chunked SSD) are themselves
validated against the naive references.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_update import (adamw_epilogue, fused_axpy,
                                        fused_dot_norms, sgd_epilogue)
from repro.kernels.mamba2_scan import mamba2_chunked
from repro.kernels.rwkv6_scan import rwkv6_chunked
from repro.kernels.sam_perturb import sam_perturb, sq_norm

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA
    (1, 128, 8, 1, 128),    # MQA, bigger head
    (2, 128, 4, 4, 32),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64), (False, None)])
def test_flash_attention_pallas_vs_reference(b, s, h, kv, hd, dtype, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("s,kv_block", [(256, 64), (512, 128)])
def test_flash_jnp_scan_vs_naive(s, kv_block):
    """The CPU/dry-run fast path is FLOP- and value-equivalent to naive."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, s, 4, 64))
    k = jax.random.normal(ks[1], (2, s, 2, 64))
    v = jax.random.normal(ks[2], (2, s, 2, 64))
    out = ref.flash_attention_jnp(q, k, v, causal=True, kv_block=kv_block)
    expect = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


def test_flash_mla_unequal_value_dim():
    """MLA decompressed attention: qk dim 48, v dim 32."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 48))
    k = jax.random.normal(ks[1], (2, 128, 4, 48))
    v = jax.random.normal(ks[2], (2, 128, 4, 32))
    out = ref.flash_attention_jnp(q, k, v, causal=True, kv_block=64)
    expect = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_masked_reference():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 64))
    k = jax.random.normal(ks[1], (2, 64, 2, 64))
    v = jax.random.normal(ks[2], (2, 64, 2, 64))
    valid = jnp.asarray(40)
    out = ref.decode_attention_jnp(q, k, v, valid)
    expect = ref.mha_reference(q, k[:, :40], v[:, :40], causal=False)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# sam perturb
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1000, 65536, 200_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sam_perturb_kernel(n, dtype):
    ks = jax.random.split(KEY, 2)
    w = jax.random.normal(ks[0], (n,), dtype)
    g = jax.random.normal(ks[1], (n,), jnp.float32)
    sn = sq_norm(g, interpret=True)
    assert float(sn) == pytest.approx(float(jnp.sum(g * g)), rel=1e-5)
    out = sam_perturb(w, g, 0.1, sn, interpret=True)
    expect = ref.sam_perturb_flat_jnp(w.astype(jnp.float32), g,
                                      jnp.float32(0.1), sn).astype(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# fused weight-space epilogue (flat-buffer update path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1000, 200_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_axpy_kernel(n, dtype):
    ks = jax.random.split(KEY, 2)
    y = jax.random.normal(ks[0], (n,), dtype)
    x = jax.random.normal(ks[1], (n,), jnp.float32)
    out = fused_axpy(0.37, x, y, interpret=True)
    expect = ref.axpy_flat_jnp(0.37, x, y)
    assert out.dtype == y.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("n", [1000, 65536, 200_001])
def test_fused_dot_norms_kernel(n):
    ks = jax.random.split(KEY, 2)
    a = jax.random.normal(ks[0], (n,))
    b = jax.random.normal(ks[1], (n,))
    got = fused_dot_norms(a, b, interpret=True)
    expect = ref.dot_norms_flat_jnp(a, b)
    for g, e in zip(got, expect):
        np.testing.assert_allclose(float(g), float(e), rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("momentum,nesterov,wd", [
    (0.9, False, 0.0),
    (0.9, True, 1e-4),
    (0.0, False, 5e-4),
])
def test_sgd_epilogue_kernel(momentum, nesterov, wd, dtype, n=200_001):
    ks = jax.random.split(KEY, 3)
    w = jax.random.normal(ks[0], (n,), dtype)
    g = jax.random.normal(ks[1], (n,), jnp.float32)
    m = jax.random.normal(ks[2], (n,), jnp.float32) if momentum else None
    w_k, m_k = sgd_epilogue(w, g, m, 0.7, 0.1, momentum=momentum,
                            nesterov=nesterov, weight_decay=wd, interpret=True)
    w_r, m_r = ref.sgd_epilogue_flat_jnp(w, g, m, 0.7, 0.1, momentum=momentum,
                                         nesterov=nesterov, weight_decay=wd)
    assert w_k.dtype == w.dtype
    np.testing.assert_allclose(np.asarray(w_k, np.float32),
                               np.asarray(w_r, np.float32), **_tol(dtype))
    if momentum:
        np.testing.assert_allclose(m_k, m_r, rtol=2e-5, atol=2e-5)
    else:
        assert m_k is None and m_r is None


@pytest.mark.parametrize("n", [1000, 65536, 200_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_delta_amax_kernel(n, dtype):
    from repro.kernels.fused_update import delta_amax
    ks = jax.random.split(KEY, 3)
    p = jax.random.normal(ks[0], (n,), dtype)
    s = jax.random.normal(ks[1], (n,), jnp.float32)
    e = 0.01 * jax.random.normal(ks[2], (n,), jnp.float32)
    got = delta_amax(p, s, e, interpret=True)
    expect = ref.delta_amax_flat_jnp(p, s, e)
    np.testing.assert_allclose(float(got), float(expect), rtol=1e-6)


@pytest.mark.parametrize("n", [1000, 200_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_delta_encode_i8_kernel(n, dtype):
    from repro.kernels.fused_update import delta_amax, delta_encode_i8
    ks = jax.random.split(KEY, 3)
    p = jax.random.normal(ks[0], (n,), dtype)
    s = jax.random.normal(ks[1], (n,), jnp.float32)
    e = 0.01 * jax.random.normal(ks[2], (n,), jnp.float32)
    from repro.service.delta import _pow2_scale
    scale = _pow2_scale(float(delta_amax(p, s, e, interpret=True)))
    q_k, s_k, e_k = delta_encode_i8(p, s, e, scale, interpret=True)
    q_r, s_r, e_r = ref.delta_encode_i8_flat_jnp(p, s, e, scale)
    assert q_k.dtype == jnp.int8 and s_k.dtype == jnp.float32
    # with the power-of-two scale the int8 payload AND the shadow advance
    # must match the oracle bit for bit (q * scale is exact in fp32, so FMA
    # contraction cannot skew the result) — that is the property that keeps
    # the client's and the server's shadows identical
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_r))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("wd", [0.0, 0.01])
def test_adamw_epilogue_kernel(wd, dtype, n=200_001):
    ks = jax.random.split(KEY, 4)
    w = jax.random.normal(ks[0], (n,), dtype)
    g = jax.random.normal(ks[1], (n,), jnp.float32)
    mu = jax.random.normal(ks[2], (n,), jnp.float32)
    nu = jnp.abs(jax.random.normal(ks[3], (n,), jnp.float32))
    args = (w, g, mu, nu, 0.7, 0.01, 0.1, 0.001)
    got = adamw_epilogue(*args, weight_decay=wd, interpret=True)
    expect = ref.adamw_epilogue_flat_jnp(*args, weight_decay=wd)
    assert got[0].dtype == w.dtype
    np.testing.assert_allclose(np.asarray(got[0], np.float32),
                               np.asarray(expect[0], np.float32), **_tol(dtype))
    for g_k, g_r in zip(got[1:], expect[1:]):
        np.testing.assert_allclose(g_k, g_r, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# mamba2 chunked SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,chunk", [(128, 32), (256, 64)])
@pytest.mark.parametrize("h,p,g,n", [(4, 32, 1, 16), (2, 16, 2, 16)])
def test_mamba2_pallas_vs_sequential(s, chunk, h, p, g, n):
    ks = jax.random.split(KEY, 4)
    B = 2
    x = jax.random.normal(ks[0], (B, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, h)))
    a = -jnp.exp(jnp.linspace(-1.0, 1.0, h))
    b = jax.random.normal(ks[2], (B, s, g, n)) * 0.3
    c = jax.random.normal(ks[3], (B, s, g, n)) * 0.3
    d = jnp.full((h,), 0.5)
    y_k, h_k = mamba2_chunked(x, dt, a, b, c, d, chunk=chunk, interpret=True)
    y_r, h_r = ref.mamba2_scan_ref(x, dt, a, b, c, d)
    np.testing.assert_allclose(y_k, y_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_k, h_r, rtol=2e-4, atol=2e-4)


def test_mamba2_chunked_jnp_vs_sequential():
    ks = jax.random.split(KEY, 4)
    B, s, h, p, g, n = 2, 128, 4, 16, 1, 8
    x = jax.random.normal(ks[0], (B, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, h)))
    a = -jnp.exp(jnp.linspace(-1.0, 1.0, h))
    b = jax.random.normal(ks[2], (B, s, g, n)) * 0.3
    c = jax.random.normal(ks[3], (B, s, g, n)) * 0.3
    d = jnp.full((h,), 0.5)
    y_c, h_c = ref.mamba2_chunked_jnp(x, dt, a, b, c, d, chunk=32)
    y_r, h_r = ref.mamba2_scan_ref(x, dt, a, b, c, d)
    np.testing.assert_allclose(y_c, y_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_c, h_r, rtol=2e-4, atol=2e-4)


def test_mamba2_state_continuation():
    """Splitting a sequence across two scans with carried state == one scan."""
    ks = jax.random.split(KEY, 4)
    B, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    x = jax.random.normal(ks[0], (B, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, h)))
    a = -jnp.exp(jnp.linspace(-1.0, 0.0, h))
    b = jax.random.normal(ks[2], (B, s, g, n)) * 0.3
    c = jax.random.normal(ks[3], (B, s, g, n)) * 0.3
    d = jnp.zeros((h,))
    y_full, h_full = ref.mamba2_scan_ref(x, dt, a, b, c, d)
    y1, h1 = ref.mamba2_scan_ref(x[:, :32], dt[:, :32], a, b[:, :32], c[:, :32], d)
    y2, h2 = ref.mamba2_scan_ref(x[:, 32:], dt[:, 32:], a, b[:, 32:], c[:, 32:],
                                 d, init_state=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h2, h_full, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 32)])
@pytest.mark.parametrize("k,v", [(16, 16), (32, 32)])
def test_rwkv6_pallas_vs_sequential(s, chunk, k, v):
    ks = jax.random.split(KEY, 5)
    B, H = 2, 2
    r = jax.random.normal(ks[0], (B, s, H, k)) * 0.5
    kk = jax.random.normal(ks[1], (B, s, H, k)) * 0.5
    vv = jax.random.normal(ks[2], (B, s, H, v)) * 0.5
    w = -jnp.exp(jax.random.normal(ks[3], (B, s, H, k)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (H, k)) * 0.1
    y_k, s_k = rwkv6_chunked(r, kk, vv, w, u, chunk=chunk, interpret=True)
    y_r, s_r = ref.rwkv6_scan_ref(r, kk, vv, w, u)
    np.testing.assert_allclose(y_k, y_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-5, atol=1e-5)


def test_rwkv6_state_continuation():
    ks = jax.random.split(KEY, 5)
    B, s, H, k = 1, 64, 2, 8
    r = jax.random.normal(ks[0], (B, s, H, k)) * 0.5
    kk = jax.random.normal(ks[1], (B, s, H, k)) * 0.5
    vv = jax.random.normal(ks[2], (B, s, H, k)) * 0.5
    w = -jnp.exp(jax.random.normal(ks[3], (B, s, H, k)) * 0.3 - 2.0)
    u = jax.random.normal(ks[4], (H, k)) * 0.1
    y_full, s_full = ref.rwkv6_scan_ref(r, kk, vv, w, u)
    y1, s1 = ref.rwkv6_scan_ref(r[:, :32], kk[:, :32], vv[:, :32], w[:, :32], u)
    y2, s2 = ref.rwkv6_scan_ref(r[:, 32:], kk[:, 32:], vv[:, 32:], w[:, 32:], u,
                                init_state=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s2, s_full, rtol=1e-5, atol=1e-5)
